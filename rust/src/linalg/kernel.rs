//! Explicit SIMD-width-aware GEMM microkernel: portable 8-lane f32
//! vectors, an `MR×NR` register-tiled inner kernel, B-panel (and, for
//! large `m`, A-panel) packing into lane-aligned scratch, and an
//! i8×i8→i32 quantized twin of the kernel for the int8 inference path.
//!
//! Every GEMM entry point in [`super::gemm`] routes through
//! [`gemm_chunk`] (unless the `scalar-gemm` feature pins the old
//! autovectorizer-dependent kernels for baseline measurements), in both
//! the serial and pool-parallel regimes — one kernel, one accumulation
//! order, everywhere.
//!
//! # Lane width
//!
//! [`F32x8`] is an array-of-8 wrapper (`#[repr(align(32))]`, one AVX
//! register worth of f32) with elementwise `add`/`mul`/[`F32x8::mul_add`].
//! It compiles on stable Rust: the elementwise loops are exactly the
//! shape LLVM's SLP vectorizer turns into `mulps`/`addps` lanes, without
//! relying on it to *discover* the vector shape in a blocked scalar GEMM
//! the way the old kernel did.  `mul_add` is by default an **unfused**
//! multiply-then-add: a fused `f32::mul_add` falls back to a libm `fmaf`
//! call on targets compiled without `+fma` (catastrophically slow) and
//! changes results by one rounding, which would break the bitwise
//! scalar↔SIMD equivalence pinned in `gemm`'s tests.  The **`fma` cargo
//! feature** switches it to a true fused `f32::mul_add` (one rounding
//! per step) for targets built with hardware FMA enabled; under that
//! feature the scalar↔SIMD comparisons relax to a ULP budget (see
//! `gemm::assert_f32s_match`) while every SIMD↔SIMD guarantee
//! (thread-count, chunking, warm-scratch bitwise determinism) is
//! unchanged, because both sides of those comparisons run the same
//! fused ops in the same order.
//!
//! # Tiling
//!
//! The microkernel computes an [`MR`]`×`[`NR`] block of C held entirely
//! in registers: `MR = 4` rows × `NR = 16` columns = 8 live [`F32x8`]
//! accumulators — enough independent dependency chains to cover FP add
//! latency, few enough to stay out of spill territory on 16-register
//! targets.  For each k step it broadcasts one A element per row and
//! multiplies two packed B lanes, so the inner loop is 2 loads + `MR`
//! broadcasts + `2·MR` multiply-adds.
//!
//! # Packing
//!
//! B is packed once per GEMM call (before the row-chunk fork, so every
//! pool task reads the same panels) into [`PackBuf`]: `NR`-wide,
//! K-major column panels, lane-aligned because the buffer stores whole
//! [`F32x8`]s.  The buffer is an alias of the dtype-generic
//! [`PanelBuf`], which backs the int8 image ([`PackBufI8`]) with the
//! same monotone-growth contract.  Packing makes the kernel's B loads
//! unit-stride and
//! cache-line aligned regardless of the source view's stride — it is
//! also where `A·Bᵀ` becomes the *same* kernel as `A·B` (the transpose
//! happens in the pack, nowhere else).  Tail panels are zero-padded to
//! `NR`; the padding multiplies into accumulator lanes that are never
//! stored, so it cannot leak into results (and a NaN/Inf in a *live*
//! lane still propagates — there is no zero-skip anywhere).
//!
//! The buffer is reusable and never shrinks: the encoder owns one inside
//! its `EncodeScratch` (via [`super::gemm::GemmScratch`]), so the warm
//! forward pass performs zero packing allocations — pinned by
//! `tests/alloc_free.rs`.
//!
//! # Determinism
//!
//! Every output element is one accumulator updated in ascending-`k`
//! order with unfused multiply-adds; K-blocking only round-trips the
//! accumulator through memory (lossless for f32).  That is the exact
//! operation sequence of the old scalar `axpy` kernel, so `A·B` results
//! are **bitwise identical** to the scalar fallback, and — as before —
//! bitwise identical for any thread cap, chunking or pool size (each
//! row's value never depends on which chunk or tile it landed in).
//!
//! # A-panel packing
//!
//! For tall GEMMs (`m ≥` [`A_PACK_MIN_M`]) the f32 entry points also
//! pack A into [`MR`]-row K-major panels ([`pack_a`]) so the inner
//! loop's broadcast loads become unit-stride.  [`gemm_chunk_pa`] reads
//! the packed A image but replays the exact per-element operation order
//! of [`gemm_chunk`], so results stay bitwise identical to the
//! unpacked path — only load addresses change.
//!
//! # Int8 path
//!
//! [`gemm_chunk_i8`] is the quantized twin: weights are quantized
//! symmetrically **per output channel** at pack time
//! ([`pack_nn_i8`]/[`pack_nt_i8`] emit one f32 scale per packed
//! column), activations **per tensor** at call time
//! ([`quantize_activations`]), products accumulate exactly in i32
//! (`k ≤` [`I8_K_MAX`] guards overflow), and the single rounding
//! happens in one dequantizing multiply per output element.  The inner
//! loop is an explicit widening lane op — [`I8x32::widening_mul_acc`]
//! (i8×i8→i16→i32) over a [`I8x32::pair_splat`] of two A values against
//! a 32-byte load of two packed B rows — so the byte-widening SIMD
//! shape is stated in the code rather than left for the autovectorizer
//! to rediscover.  Because integer accumulation is exact, the two-half
//! partial sums fold to the same totals as serial accumulation, and
//! int8 results stay bitwise identical across thread counts, chunkings
//! *and* this loop restructure *by construction*.  Zero channels
//! (and zero tensors) get scale 0 so their outputs dequantize to exact
//! zeros; NaN quantizes to 0, i.e. the int8 path does not propagate
//! NaN the way the f32 path does.

use super::MatView;

/// f32 lanes per vector — one 256-bit register.
pub const LANES: usize = 8;
/// Microkernel rows (A elements broadcast per k step).
pub const MR: usize = 4;
/// Microkernel columns (two [`F32x8`]s wide).
pub const NR: usize = 2 * LANES;
/// K-blocking depth: one `KC × NR` packed panel slice is ≤ 16 KiB, so
/// the panel the inner loop streams stays L1-resident.
pub const KC: usize = 256;

/// Portable 8-lane f32 vector: an aligned array the optimizer lowers to
/// one SIMD register.  All ops are elementwise; `mul_add` is unfused
/// (see module docs).
#[derive(Debug, Clone, Copy)]
#[repr(C, align(32))]
pub struct F32x8(pub [f32; LANES]);

// lint: hot-path — lane ops run per k-step in every GEMM inner loop
impl F32x8 {
    pub const ZERO: F32x8 = F32x8([0.0; LANES]);

    #[inline(always)]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; LANES])
    }

    /// Load the first [`LANES`] values of `src`.
    #[inline(always)]
    pub fn load(src: &[f32]) -> F32x8 {
        let mut out = [0.0; LANES];
        out.copy_from_slice(&src[..LANES]);
        F32x8(out)
    }

    /// Load up to [`LANES`] values; missing lanes are zero.
    #[inline(always)]
    pub fn load_partial(src: &[f32]) -> F32x8 {
        let n = src.len().min(LANES);
        let mut out = [0.0; LANES];
        out[..n].copy_from_slice(&src[..n]);
        F32x8(out)
    }

    /// Store all lanes into the first [`LANES`] slots of `dst`.
    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        dst[..LANES].copy_from_slice(&self.0);
    }

    /// Store only the first `min(dst.len(), LANES)` lanes.
    #[inline(always)]
    pub fn store_partial(self, dst: &mut [f32]) {
        let n = dst.len().min(LANES);
        dst[..n].copy_from_slice(&self.0[..n]);
    }

    /// `self * a + b`, elementwise.  Default build: a separate multiply
    /// and add (not IEEE-fused) — bitwise identical to the scalar
    /// kernel's `acc += x * y` on every target.  With the `fma` cargo
    /// feature: a true fused `f32::mul_add`, one rounding per step (see
    /// module docs for what that relaxes).
    #[inline(always)]
    pub fn mul_add(self, a: F32x8, b: F32x8) -> F32x8 {
        let mut out = [0.0; LANES];
        for i in 0..LANES {
            #[cfg(not(feature = "fma"))]
            {
                out[i] = self.0[i] * a.0[i] + b.0[i];
            }
            #[cfg(feature = "fma")]
            {
                out[i] = self.0[i].mul_add(a.0[i], b.0[i]);
            }
        }
        F32x8(out)
    }

    #[inline(always)]
    pub fn add(self, o: F32x8) -> F32x8 {
        let mut out = [0.0; LANES];
        for i in 0..LANES {
            out[i] = self.0[i] + o.0[i];
        }
        F32x8(out)
    }

    #[inline(always)]
    pub fn mul(self, o: F32x8) -> F32x8 {
        let mut out = [0.0; LANES];
        for i in 0..LANES {
            out[i] = self.0[i] * o.0[i];
        }
        F32x8(out)
    }

    /// Horizontal sum in a fixed pairwise tree —
    /// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` — so reductions are
    /// deterministic across targets.
    #[inline(always)]
    pub fn hsum(self) -> f32 {
        let l = self.0;
        ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
    }
}
// lint: end-hot-path

/// i8 lanes per vector — one 256-bit register of bytes.
pub const I8_LANES: usize = 32;

/// Portable 32-lane i8 vector: the int8 kernel's packing/alignment
/// unit *and* its compute type.  [`I8x32::widening_mul_acc`] is the
/// explicit i8×i8→i16→i32 multiply-accumulate the quantized inner loop
/// runs on — the elementwise widen-multiply-add loop is exactly the
/// shape LLVM lowers to `pmaddubsw`/`pmaddwd`-class byte ops, so the
/// kernel no longer leans on the autovectorizer discovering the widening
/// pattern in blocked scalar i32 code.
#[derive(Debug, Clone, Copy)]
#[repr(C, align(32))]
pub struct I8x32(pub [i8; I8_LANES]);

// lint: hot-path — i8 lane ops run per k-step pair in the int8 kernel
impl I8x32 {
    pub const ZERO: I8x32 = I8x32([0; I8_LANES]);

    /// Load the first [`I8_LANES`] values of `src`.
    #[inline(always)]
    pub fn load(src: &[i8]) -> I8x32 {
        let mut out = [0; I8_LANES];
        out.copy_from_slice(&src[..I8_LANES]);
        I8x32(out)
    }

    /// Load up to [`I8_LANES`] values; missing lanes are zero.
    #[inline(always)]
    pub fn load_partial(src: &[i8]) -> I8x32 {
        let n = src.len().min(I8_LANES);
        let mut out = [0; I8_LANES];
        out[..n].copy_from_slice(&src[..n]);
        I8x32(out)
    }

    /// Broadcast a *pair* of A values across the two 16-lane halves:
    /// lanes `[0, NR)` hold `lo`, lanes `[NR, 2·NR)` hold `hi`.  Pairs
    /// with a [`I8x32::load`] of two consecutive K-major packed B rows
    /// (`NR` = 16 columns each), so one vector op covers two k steps.
    #[inline(always)]
    pub fn pair_splat(lo: i8, hi: i8) -> I8x32 {
        let mut out = [hi; I8_LANES];
        out[..NR].fill(lo);
        I8x32(out)
    }

    /// Explicit widening multiply-accumulate: per lane,
    /// `acc[i] += (self[i] as i16 · o[i] as i16) as i32`.  The i16
    /// intermediate is exact (|i8·i8| ≤ 128² < 2¹⁵) and the i32
    /// accumulate is exact under the [`I8_K_MAX`] bound, so totals are
    /// bitwise identical to any other summation order of the same
    /// integer products.
    #[inline(always)]
    pub fn widening_mul_acc(self, o: I8x32, acc: &mut [i32; I8_LANES]) {
        for i in 0..I8_LANES {
            let p = self.0[i] as i16 * o.0[i] as i16;
            acc[i] += p as i32;
        }
    }
}
// lint: end-hot-path

/// Element/lane pairing for [`PanelBuf`]: one `Lane` is a whole SIMD
/// register of `Elem`s, the allocation unit that keeps packed panels
/// register-aligned whatever the element dtype.
///
/// # Safety
///
/// Implementors must be `#[repr(C)]` arrays of exactly `WIDTH` `Elem`s
/// with no padding and alignment ≥ `Elem`'s — [`PanelBuf`] reinterprets
/// lane storage as a flat `Elem` slice.
pub unsafe trait Lane: Copy + std::fmt::Debug + 'static {
    type Elem: Copy + std::fmt::Debug + 'static;
    const ZERO_LANE: Self;
    const WIDTH: usize;
}

// SAFETY: repr(C) array of exactly LANES f32s, align(32) ≥ align(f32).
unsafe impl Lane for F32x8 {
    type Elem = f32;
    const ZERO_LANE: F32x8 = F32x8::ZERO;
    const WIDTH: usize = LANES;
}

// SAFETY: repr(C) array of exactly I8_LANES i8s, align(32) ≥ align(i8).
unsafe impl Lane for I8x32 {
    type Elem = i8;
    const ZERO_LANE: I8x32 = I8x32::ZERO;
    const WIDTH: usize = I8_LANES;
}

/// Reusable, lane-aligned packing scratch, generic over element dtype.
/// Backed by whole [`Lane`]s so the panel base is always 32-byte
/// aligned; grows monotonically and never shrinks, so a warm caller
/// (the encoder scratch, the thread-local fallback in `gemm`) packs
/// allocation-free.  Also the storage behind the immutable per-model
/// panel cache (`gemm::PackedPanels`), consumed through [`PanelBuf::flat`].
#[derive(Debug)]
pub struct PanelBuf<L: Lane> {
    lanes: Vec<L>,
}

/// The f32 packing scratch every f32 GEMM call uses.
pub type PackBuf = PanelBuf<F32x8>;
/// The i8 image buffer behind quantized packs and activation scratch.
pub type PackBufI8 = PanelBuf<I8x32>;

impl<L: Lane> Default for PanelBuf<L> {
    fn default() -> Self {
        PanelBuf { lanes: Vec::new() }
    }
}

impl<L: Lane> PanelBuf<L> {
    pub fn new() -> PanelBuf<L> {
        PanelBuf::default()
    }

    /// Current capacity in elements (tests assert warm stability).
    pub fn capacity_elems(&self) -> usize {
        self.lanes.capacity() * L::WIDTH
    }

    /// Base pointer — lets buffer-reuse tests assert no reallocation.
    pub fn as_elem_ptr(&self) -> *const L::Elem {
        self.lanes.as_ptr().cast()
    }

    /// Grow (never shrink) to at least `elems` and return the flat
    /// mutable view of exactly that many elements.
    fn flat_mut(&mut self, elems: usize) -> &mut [L::Elem] {
        let need = (elems + L::WIDTH - 1) / L::WIDTH;
        if self.lanes.len() < need {
            self.lanes.resize(need, L::ZERO_LANE);
        }
        // SAFETY: per the Lane contract, lane storage is a padding-free
        // repr(C) array of WIDTH Elems with sufficient alignment, so a
        // lane slice reinterprets soundly as an Elem slice of WIDTH×
        // the length; `need` lanes cover `elems` elements.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.lanes.as_mut_ptr().cast::<L::Elem>(),
                elems,
            )
        }
    }

    /// Immutable flat view of the first `elems` elements — how a
    /// previously packed image (e.g. a cached weight panel) is consumed
    /// without re-packing.
    pub fn flat(&self, elems: usize) -> &[L::Elem] {
        assert!(
            elems <= self.lanes.len() * L::WIDTH,
            "flat view of {elems} elems beyond packed image"
        );
        // SAFETY: same layout argument as `flat_mut`, shared borrow.
        unsafe {
            std::slice::from_raw_parts(
                self.lanes.as_ptr().cast::<L::Elem>(),
                elems,
            )
        }
    }
}

/// f32-named conveniences preserved from the pre-generic `PackBuf`.
impl PackBuf {
    /// Current capacity in floats (tests assert warm stability).
    pub fn capacity_floats(&self) -> usize {
        self.capacity_elems()
    }

    /// Base pointer — lets buffer-reuse tests assert no reallocation.
    pub fn as_ptr(&self) -> *const f32 {
        self.as_elem_ptr()
    }
}

// lint: hot-path — f32 packing runs on every warm GEMM call
/// Number of [`NR`]-wide panels covering `n` columns.
#[inline]
pub fn panels(n: usize) -> usize {
    (n + NR - 1) / NR
}

/// Pack `b` (k × n, the `A·B` orientation) into K-major `NR`-wide
/// panels: element `(kk, j0+jj)` lands at `(p·k + kk)·NR + jj` for panel
/// `p = j0/NR`.  Tail-panel columns beyond `n` are zeroed.
pub fn pack_nn<'a>(buf: &'a mut PackBuf, b: MatView<'_>) -> &'a [f32] {
    let (k, n) = (b.rows, b.cols);
    let dst = buf.flat_mut(panels(n) * k * NR);
    for p in 0..panels(n) {
        let j0 = p * NR;
        let w = (n - j0).min(NR);
        let base = p * k * NR;
        for kk in 0..k {
            let o = base + kk * NR;
            dst[o..o + w].copy_from_slice(&b.row(kk)[j0..j0 + w]);
            dst[o + w..o + NR].fill(0.0);
        }
    }
    dst
}

/// Pack `b` (n × k, the `A·Bᵀ` orientation: C column `j` is B *row* `j`)
/// into the same K-major panel layout as [`pack_nn`] — the transpose
/// happens here, so the microkernel never sees it.
pub fn pack_nt<'a>(buf: &'a mut PackBuf, b: MatView<'_>) -> &'a [f32] {
    let (n, k) = (b.rows, b.cols);
    let dst = buf.flat_mut(panels(n) * k * NR);
    for p in 0..panels(n) {
        let j0 = p * NR;
        let w = (n - j0).min(NR);
        let base = p * k * NR;
        for jj in 0..w {
            let row = b.row(j0 + jj);
            for (kk, &v) in row.iter().enumerate() {
                dst[base + kk * NR + jj] = v;
            }
        }
        for jj in w..NR {
            for kk in 0..k {
                dst[base + kk * NR + jj] = 0.0;
            }
        }
    }
    dst
}
// lint: end-hot-path

/// Largest inner dimension the i8 kernel accepts: `127·127·k` must stay
/// below `i32::MAX` so integer accumulation cannot overflow.  Any larger
/// `k` would need i64 or split accumulation; model dimensions here are
/// orders of magnitude smaller.
pub const I8_K_MAX: usize = (i32::MAX / (127 * 127)) as usize;

/// Symmetric quantization scale for one channel/tensor with magnitude
/// `max_abs`: returns `(scale, inv_scale)` = `(max_abs/127, 127/max_abs)`,
/// or `(0, 0)` for an all-zero (or padding) channel — quantized values
/// are then 0 and the dequant multiply reproduces exact zeros.
#[inline]
fn quant_scale(max_abs: f32) -> (f32, f32) {
    if max_abs > 0.0 {
        (max_abs / 127.0, 127.0 / max_abs)
    } else {
        (0.0, 0.0)
    }
}

/// Round-to-nearest (ties away from zero) symmetric quantization of one
/// value at inverse scale `inv`.  NaN maps to 0 like any saturating
/// float→int cast.
#[inline(always)]
fn quantize(v: f32, inv: f32) -> i8 {
    (v * inv).round().clamp(-127.0, 127.0) as i8
}

/// Quantize-and-pack `b` (k × n, the `A·B` orientation) into i8 panels
/// with the same K-major `NR`-wide layout as [`pack_nn`], extracting one
/// symmetric per-output-channel scale per column into `scales` (resized
/// to `panels(n)·NR`; padding columns get scale 0 and zero lanes).
pub fn pack_nn_i8<'a>(
    buf: &'a mut PackBufI8,
    scales: &mut Vec<f32>,
    b: MatView<'_>,
) -> &'a [i8] {
    let (k, n) = (b.rows, b.cols);
    scales.clear();
    scales.resize(panels(n) * NR, 0.0);
    // inverse scales are a pack-time temporary: this runs once per
    // weight generation (cache build), never in the warm hot path
    let mut invs = vec![0.0f32; n];
    for (j, inv) in invs.iter_mut().enumerate() {
        let mut max_abs = 0.0f32;
        for kk in 0..k {
            max_abs = max_abs.max(b.row(kk)[j].abs());
        }
        let (s, i) = quant_scale(max_abs);
        scales[j] = s;
        *inv = i;
    }
    let dst = buf.flat_mut(panels(n) * k * NR);
    for p in 0..panels(n) {
        let j0 = p * NR;
        let w = (n - j0).min(NR);
        let base = p * k * NR;
        for kk in 0..k {
            let o = base + kk * NR;
            let row = b.row(kk);
            for jj in 0..w {
                dst[o + jj] = quantize(row[j0 + jj], invs[j0 + jj]);
            }
            dst[o + w..o + NR].fill(0);
        }
    }
    dst
}

/// Quantize-and-pack `b` (n × k, the `A·Bᵀ` orientation) into the same
/// i8 panel layout as [`pack_nn_i8`]; output channel `j` is B *row* `j`,
/// so the per-channel magnitude scans are contiguous.
pub fn pack_nt_i8<'a>(
    buf: &'a mut PackBufI8,
    scales: &mut Vec<f32>,
    b: MatView<'_>,
) -> &'a [i8] {
    let (n, k) = (b.rows, b.cols);
    scales.clear();
    scales.resize(panels(n) * NR, 0.0);
    let dst = buf.flat_mut(panels(n) * k * NR);
    for p in 0..panels(n) {
        let j0 = p * NR;
        let w = (n - j0).min(NR);
        let base = p * k * NR;
        for jj in 0..w {
            let row = b.row(j0 + jj);
            let mut max_abs = 0.0f32;
            for &v in row {
                max_abs = max_abs.max(v.abs());
            }
            let (s, inv) = quant_scale(max_abs);
            scales[j0 + jj] = s;
            for (kk, &v) in row.iter().enumerate() {
                dst[base + kk * NR + jj] = quantize(v, inv);
            }
        }
        for jj in w..NR {
            for kk in 0..k {
                dst[base + kk * NR + jj] = 0;
            }
        }
    }
    dst
}

// lint: hot-path — per-call quantization, A-packing and every register
// tile run inside warm GEMMs; nothing here may touch the heap
/// Dynamic per-tensor symmetric quantization of an activation view into
/// a reusable i8 buffer (row-major m × k).  Returns the quantized image
/// and the tensor scale.  Runs once per GEMM call on the calling thread
/// *before* the row-chunk fork, so every worker reads the same image
/// and results stay thread-count-independent.
pub fn quantize_activations<'a>(
    buf: &'a mut PackBufI8,
    a: MatView<'_>,
) -> (&'a [i8], f32) {
    let m = a.rows;
    let mut max_abs = 0.0f32;
    for i in 0..m {
        for &v in a.row(i) {
            max_abs = max_abs.max(v.abs());
        }
    }
    quantize_activations_with_max(buf, a, max_abs)
}

/// [`quantize_activations`] with the max-abs scan replaced by a
/// caller-supplied magnitude — the static activation-quantization path:
/// the encoder's per-site scale cache observed the tensor range during
/// calibration, so the warm call skips one full read of A.  Values
/// beyond `max_abs` saturate at ±127, the same clamp the dynamic path
/// applies to its own maximum.  Returns the quantized image and the
/// tensor scale (`max_abs / 127`).
pub fn quantize_activations_with_max<'a>(
    buf: &'a mut PackBufI8,
    a: MatView<'_>,
    max_abs: f32,
) -> (&'a [i8], f32) {
    let (m, k) = (a.rows, a.cols);
    let (scale, inv) = quant_scale(max_abs);
    let dst = buf.flat_mut(m * k);
    for i in 0..m {
        let row = a.row(i);
        for (o, &v) in dst[i * k..(i + 1) * k].iter_mut().zip(row) {
            *o = quantize(v, inv);
        }
    }
    (dst, scale)
}

/// i8×i8→i32 twin of [`gemm_chunk`]: one contiguous row chunk of
/// `C = (a_scale · scales[j]) · (Aq · Bq)` against a pre-quantized,
/// pre-packed B image ([`pack_nn_i8`]/[`pack_nt_i8`]).
///
/// `aq` is the whole quantized activation matrix (row-major, row stride
/// `k`); `row0` indexes into it globally, like the f32 kernel's
/// `MatView`.  Integer accumulation is exact, so — unlike the f32
/// kernel, which must pin its operation order — results are bitwise
/// identical across thread counts and chunkings *by construction*; the
/// one rounding per element happens in the dequantizing multiply.
/// Register tiling: [`MR`] rows × [`I8_LANES`] i32 accumulators — two
/// [`NR`]-wide halves (even-k / odd-k partials, folded once at store
/// time) fed by [`I8x32::widening_mul_acc`] over [`I8x32::pair_splat`]
/// broadcasts, two k steps per vector op.
#[allow(clippy::too_many_arguments)]
pub fn gemm_chunk_i8(
    aq: &[i8],
    row0: usize,
    packed: &[i8],
    k: usize,
    n: usize,
    a_scale: f32,
    scales: &[f32],
    c: &mut [f32],
    cs: usize,
    col0: usize,
) {
    let rows = c.len() / cs;
    if k == 0 {
        for i in 0..rows {
            c[i * cs + col0..i * cs + col0 + n].fill(0.0);
        }
        return;
    }
    assert!(k <= I8_K_MAX, "i8 GEMM inner dim {k} could overflow i32");
    for p in 0..panels(n) {
        let j0 = p * NR;
        let nr = (n - j0).min(NR);
        let base = p * k * NR;
        let mut i0 = 0;
        while i0 < rows {
            let mr = (rows - i0).min(MR);
            // one I8x32-shaped accumulator image per row: lanes [0, NR)
            // hold the even-k partial sums, lanes [NR, 2·NR) the odd-k
            // partials; integer addition is exact, so folding the two
            // halves at the end reproduces the serial total bitwise
            let mut acc = [[0i32; I8_LANES]; MR];
            let mut kk = 0;
            while kk + 2 <= k {
                // two consecutive K-major packed B rows = one full
                // 32-byte vector load
                let b2 = I8x32::load(&packed[base + kk * NR..]);
                for (r, acc_r) in acc.iter_mut().enumerate().take(mr) {
                    let arow = (row0 + i0 + r) * k;
                    let a2 = I8x32::pair_splat(aq[arow + kk], aq[arow + kk + 1]);
                    a2.widening_mul_acc(b2, acc_r);
                }
                kk += 2;
            }
            if kk < k {
                // odd tail: upper half loads zeros and splats zero, so
                // the odd-k partials gain exactly nothing
                let b2 = I8x32::load_partial(
                    &packed[base + kk * NR..base + (kk + 1) * NR],
                );
                for (r, acc_r) in acc.iter_mut().enumerate().take(mr) {
                    let av = aq[(row0 + i0 + r) * k + kk];
                    I8x32::pair_splat(av, 0).widening_mul_acc(b2, acc_r);
                }
            }
            for (r, acc_r) in acc.iter().enumerate().take(mr) {
                let cbase = (i0 + r) * cs + col0 + j0;
                for (jj, o) in c[cbase..cbase + nr].iter_mut().enumerate() {
                    let total = acc_r[jj] + acc_r[jj + NR];
                    *o = total as f32 * (a_scale * scales[j0 + jj]);
                }
            }
            i0 += MR;
        }
    }
}

/// Number of [`MR`]-row panels covering `m` rows.
#[inline]
pub fn row_panels(m: usize) -> usize {
    (m + MR - 1) / MR
}

/// Minimum `m` at which the f32 entry points also pack A into
/// [`MR`]-row panels: the pack is one extra pass over A, repaid by
/// unit-stride broadcast loads once each A row is re-read `panels(n)`
/// times.  For short A (a handful of tile rows) the pass costs more
/// than it saves.
pub const A_PACK_MIN_M: usize = 48;

/// Pack `a` (m × k, possibly a strided view) into K-major [`MR`]-row
/// panels: element `(i0+ii, kk)` lands at `(rp·k + kk)·MR + ii` for
/// row-panel `rp = i0/MR`; tail rows zero-pad into accumulator rows
/// that are never stored.  Same values in the same accumulation order
/// as reading A directly, so packed-A GEMMs stay bitwise identical.
pub fn pack_a<'a>(buf: &'a mut PackBuf, a: MatView<'_>) -> &'a [f32] {
    let (m, k) = (a.rows, a.cols);
    let dst = buf.flat_mut(row_panels(m) * k * MR);
    for rp in 0..row_panels(m) {
        let i0 = rp * MR;
        let h = (m - i0).min(MR);
        let base = rp * k * MR;
        for ii in 0..h {
            let row = a.row(i0 + ii);
            for (kk, &v) in row.iter().enumerate() {
                dst[base + kk * MR + ii] = v;
            }
        }
        for ii in h..MR {
            for kk in 0..k {
                dst[base + kk * MR + ii] = 0.0;
            }
        }
    }
    dst
}

/// Full `MR × NR` register tile over one K-block.
///
/// `c` starts at the tile origin with row stride `cs`; `first` means
/// this is the k0 == 0 block, so accumulators start at zero instead of
/// reloading C (C may hold stale garbage — see `matmul_view_cols`).
#[inline(always)]
fn tile_full(
    a: MatView<'_>,
    row0: usize,
    k0: usize,
    kc: usize,
    panel: &[f32],
    c: &mut [f32],
    cs: usize,
    first: bool,
) {
    let a0 = &a.row(row0)[k0..k0 + kc];
    let a1 = &a.row(row0 + 1)[k0..k0 + kc];
    let a2 = &a.row(row0 + 2)[k0..k0 + kc];
    let a3 = &a.row(row0 + 3)[k0..k0 + kc];
    let (mut c00, mut c01, mut c10, mut c11, mut c20, mut c21, mut c30, mut c31) =
        if first {
            let z = F32x8::ZERO;
            (z, z, z, z, z, z, z, z)
        } else {
            (
                F32x8::load(&c[0..]),
                F32x8::load(&c[LANES..]),
                F32x8::load(&c[cs..]),
                F32x8::load(&c[cs + LANES..]),
                F32x8::load(&c[2 * cs..]),
                F32x8::load(&c[2 * cs + LANES..]),
                F32x8::load(&c[3 * cs..]),
                F32x8::load(&c[3 * cs + LANES..]),
            )
        };
    for kk in 0..kc {
        let b0 = F32x8::load(&panel[kk * NR..]);
        let b1 = F32x8::load(&panel[kk * NR + LANES..]);
        let s0 = F32x8::splat(a0[kk]);
        c00 = b0.mul_add(s0, c00);
        c01 = b1.mul_add(s0, c01);
        let s1 = F32x8::splat(a1[kk]);
        c10 = b0.mul_add(s1, c10);
        c11 = b1.mul_add(s1, c11);
        let s2 = F32x8::splat(a2[kk]);
        c20 = b0.mul_add(s2, c20);
        c21 = b1.mul_add(s2, c21);
        let s3 = F32x8::splat(a3[kk]);
        c30 = b0.mul_add(s3, c30);
        c31 = b1.mul_add(s3, c31);
    }
    c00.store(&mut c[0..]);
    c01.store(&mut c[LANES..]);
    c10.store(&mut c[cs..]);
    c11.store(&mut c[cs + LANES..]);
    c20.store(&mut c[2 * cs..]);
    c21.store(&mut c[2 * cs + LANES..]);
    c30.store(&mut c[3 * cs..]);
    c31.store(&mut c[3 * cs + LANES..]);
}

/// Edge tile: `mr ≤ MR` rows, `nr ≤ NR` live columns (partial loads and
/// stores; padded panel lanes accumulate into lanes that are never
/// stored).  Same per-element operation order as [`tile_full`], so a
/// row's value does not depend on which tile shape computed it.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn tile_edge(
    a: MatView<'_>,
    row0: usize,
    mr: usize,
    k0: usize,
    kc: usize,
    panel: &[f32],
    c: &mut [f32],
    cs: usize,
    nr: usize,
    first: bool,
) {
    let mut acc = [[F32x8::ZERO; 2]; MR];
    if !first {
        for (r, acc_r) in acc.iter_mut().enumerate().take(mr) {
            let row = &c[r * cs..r * cs + nr];
            acc_r[0] = F32x8::load_partial(row);
            acc_r[1] = F32x8::load_partial(&row[row.len().min(LANES)..]);
        }
    }
    for kk in 0..kc {
        let b0 = F32x8::load(&panel[kk * NR..]);
        let b1 = F32x8::load(&panel[kk * NR + LANES..]);
        for (r, acc_r) in acc.iter_mut().enumerate().take(mr) {
            let s = F32x8::splat(a.row(row0 + r)[k0 + kk]);
            acc_r[0] = b0.mul_add(s, acc_r[0]);
            acc_r[1] = b1.mul_add(s, acc_r[1]);
        }
    }
    for (r, acc_r) in acc.iter().enumerate().take(mr) {
        let row = &mut c[r * cs..r * cs + nr];
        let split = row.len().min(LANES);
        let (lo, hi) = row.split_at_mut(split);
        acc_r[0].store_partial(lo);
        acc_r[1].store_partial(hi);
    }
}

/// Compute one contiguous row chunk of a GEMM against pre-packed B.
///
/// `c` holds `rows = c.len()/cs` output rows of stride `cs`; the live
/// output block is columns `[col0, col0 + n)` of each row (other
/// columns are untouched).  `row0` is the chunk's global row offset
/// into A; `packed` is the full [`pack_nn`]/[`pack_nt`] image with
/// inner dimension `k`.  This is the one kernel every `gemm` entry
/// point funnels into.
#[allow(clippy::too_many_arguments)]
pub fn gemm_chunk(
    a: MatView<'_>,
    row0: usize,
    packed: &[f32],
    k: usize,
    n: usize,
    c: &mut [f32],
    cs: usize,
    col0: usize,
) {
    let rows = c.len() / cs;
    if k == 0 {
        // no accumulation steps: the contract is still "block fully
        // overwritten", i.e. zeros
        for i in 0..rows {
            c[i * cs + col0..i * cs + col0 + n].fill(0.0);
        }
        return;
    }
    for p in 0..panels(n) {
        let j0 = p * NR;
        let nr = (n - j0).min(NR);
        let base = p * k * NR;
        let mut k0 = 0;
        while k0 < k {
            let kc = (k - k0).min(KC);
            let panel = &packed[base + k0 * NR..base + (k0 + kc) * NR];
            let first = k0 == 0;
            let mut i0 = 0;
            while i0 < rows {
                let mr = (rows - i0).min(MR);
                let cbase = i0 * cs + col0 + j0;
                if mr == MR && nr == NR {
                    tile_full(a, row0 + i0, k0, kc, panel, &mut c[cbase..], cs, first);
                } else {
                    tile_edge(
                        a,
                        row0 + i0,
                        mr,
                        k0,
                        kc,
                        panel,
                        &mut c[cbase..],
                        cs,
                        nr,
                        first,
                    );
                }
                i0 += MR;
            }
            k0 += kc;
        }
    }
}

/// [`tile_full`] reading A from a packed [`MR`]-row panel slice
/// (`apanel[kk·MR + r]`): identical splat/mul_add sequence, so values
/// are bitwise-equal to the unpacked tile.
#[inline(always)]
fn tile_full_pa(
    apanel: &[f32],
    kc: usize,
    panel: &[f32],
    c: &mut [f32],
    cs: usize,
    first: bool,
) {
    let (mut c00, mut c01, mut c10, mut c11, mut c20, mut c21, mut c30, mut c31) =
        if first {
            let z = F32x8::ZERO;
            (z, z, z, z, z, z, z, z)
        } else {
            (
                F32x8::load(&c[0..]),
                F32x8::load(&c[LANES..]),
                F32x8::load(&c[cs..]),
                F32x8::load(&c[cs + LANES..]),
                F32x8::load(&c[2 * cs..]),
                F32x8::load(&c[2 * cs + LANES..]),
                F32x8::load(&c[3 * cs..]),
                F32x8::load(&c[3 * cs + LANES..]),
            )
        };
    for kk in 0..kc {
        let b0 = F32x8::load(&panel[kk * NR..]);
        let b1 = F32x8::load(&panel[kk * NR + LANES..]);
        let arow = &apanel[kk * MR..kk * MR + MR];
        let s0 = F32x8::splat(arow[0]);
        c00 = b0.mul_add(s0, c00);
        c01 = b1.mul_add(s0, c01);
        let s1 = F32x8::splat(arow[1]);
        c10 = b0.mul_add(s1, c10);
        c11 = b1.mul_add(s1, c11);
        let s2 = F32x8::splat(arow[2]);
        c20 = b0.mul_add(s2, c20);
        c21 = b1.mul_add(s2, c21);
        let s3 = F32x8::splat(arow[3]);
        c30 = b0.mul_add(s3, c30);
        c31 = b1.mul_add(s3, c31);
    }
    c00.store(&mut c[0..]);
    c01.store(&mut c[LANES..]);
    c10.store(&mut c[cs..]);
    c11.store(&mut c[cs + LANES..]);
    c20.store(&mut c[2 * cs..]);
    c21.store(&mut c[2 * cs + LANES..]);
    c30.store(&mut c[3 * cs..]);
    c31.store(&mut c[3 * cs + LANES..]);
}

/// [`tile_edge`] reading A from a packed panel (zero-padded tail rows
/// feed accumulator rows that are never stored).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn tile_edge_pa(
    apanel: &[f32],
    mr: usize,
    kc: usize,
    panel: &[f32],
    c: &mut [f32],
    cs: usize,
    nr: usize,
    first: bool,
) {
    let mut acc = [[F32x8::ZERO; 2]; MR];
    if !first {
        for (r, acc_r) in acc.iter_mut().enumerate().take(mr) {
            let row = &c[r * cs..r * cs + nr];
            acc_r[0] = F32x8::load_partial(row);
            acc_r[1] = F32x8::load_partial(&row[row.len().min(LANES)..]);
        }
    }
    for kk in 0..kc {
        let b0 = F32x8::load(&panel[kk * NR..]);
        let b1 = F32x8::load(&panel[kk * NR + LANES..]);
        let arow = &apanel[kk * MR..kk * MR + MR];
        for (r, acc_r) in acc.iter_mut().enumerate().take(mr) {
            let s = F32x8::splat(arow[r]);
            acc_r[0] = b0.mul_add(s, acc_r[0]);
            acc_r[1] = b1.mul_add(s, acc_r[1]);
        }
    }
    for (r, acc_r) in acc.iter().enumerate().take(mr) {
        let row = &mut c[r * cs..r * cs + nr];
        let split = row.len().min(LANES);
        let (lo, hi) = row.split_at_mut(split);
        acc_r[0].store_partial(lo);
        acc_r[1].store_partial(hi);
    }
}

/// [`gemm_chunk`] against pre-packed A panels ([`pack_a`]): same
/// panels, K-blocks, tile shapes and per-element operation order, so
/// output is bitwise identical to the unpacked-A kernel — only A's load
/// addresses change.  `row0` (the chunk's global row offset) must be
/// [`MR`]-aligned so chunk-local tiles coincide with pack panels;
/// `gemm`'s chunker rounds its row splits up to `MR` for this path.
#[allow(clippy::too_many_arguments)]
pub fn gemm_chunk_pa(
    apack: &[f32],
    row0: usize,
    packed: &[f32],
    k: usize,
    n: usize,
    c: &mut [f32],
    cs: usize,
    col0: usize,
) {
    debug_assert_eq!(row0 % MR, 0, "packed-A chunks must be MR-aligned");
    let rows = c.len() / cs;
    if k == 0 {
        for i in 0..rows {
            c[i * cs + col0..i * cs + col0 + n].fill(0.0);
        }
        return;
    }
    for p in 0..panels(n) {
        let j0 = p * NR;
        let nr = (n - j0).min(NR);
        let base = p * k * NR;
        let mut k0 = 0;
        while k0 < k {
            let kc = (k - k0).min(KC);
            let panel = &packed[base + k0 * NR..base + (k0 + kc) * NR];
            let first = k0 == 0;
            let mut i0 = 0;
            while i0 < rows {
                let mr = (rows - i0).min(MR);
                let abase = (row0 + i0) / MR * (k * MR);
                let apanel = &apack[abase + k0 * MR..abase + (k0 + kc) * MR];
                let cbase = i0 * cs + col0 + j0;
                if mr == MR && nr == NR {
                    tile_full_pa(apanel, kc, panel, &mut c[cbase..], cs, first);
                } else {
                    tile_edge_pa(
                        apanel,
                        mr,
                        kc,
                        panel,
                        &mut c[cbase..],
                        cs,
                        nr,
                        first,
                    );
                }
                i0 += MR;
            }
            k0 += kc;
        }
    }
}
// lint: end-hot-path

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn f32x8_elementwise_ops() {
        let a = F32x8::splat(2.0);
        let b = F32x8([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.mul(b).0, [2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0, 16.0]);
        assert_eq!(a.add(b).0[7], 10.0);
        // mul_add = self*a + b, unfused
        let r = b.mul_add(a, F32x8::splat(1.0));
        assert_eq!(r.0, [3.0, 5.0, 7.0, 9.0, 11.0, 13.0, 15.0, 17.0]);
        assert_eq!(b.hsum(), 36.0);
    }

    #[test]
    fn partial_load_store_respect_bounds() {
        let v = F32x8::load_partial(&[1.0, 2.0, 3.0]);
        assert_eq!(v.0, [1.0, 2.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let mut out = [9.0f32; 5];
        v.store_partial(&mut out);
        assert_eq!(out, [1.0, 2.0, 3.0, 0.0, 0.0]);
        // empty slices are fine
        assert_eq!(F32x8::load_partial(&[]).0, [0.0; LANES]);
        F32x8::splat(1.0).store_partial(&mut []);
    }

    #[test]
    fn pack_nn_layout_and_zero_padding() {
        // 3×5 B: panel 0 holds all 5 columns + 11 zeros per k row
        let b = Mat::filled_with(3, 5, |r, c| (r * 10 + c) as f32);
        let mut buf = PackBuf::new();
        let packed = pack_nn(&mut buf, MatView::full(&b));
        assert_eq!(packed.len(), 3 * NR);
        for kk in 0..3 {
            for jj in 0..5 {
                assert_eq!(packed[kk * NR + jj], (kk * 10 + jj) as f32);
            }
            for jj in 5..NR {
                assert_eq!(packed[kk * NR + jj], 0.0, "pad must be zero");
            }
        }
    }

    #[test]
    fn pack_nt_transposes_into_panels() {
        // B is (n=18 × k=3): two panels; element (kk, j) = b[j][kk]
        let b = Mat::filled_with(18, 3, |r, c| (r * 100 + c) as f32);
        let mut buf = PackBuf::new();
        let packed = pack_nt(&mut buf, MatView::full(&b));
        assert_eq!(packed.len(), 2 * 3 * NR);
        // panel 0, kk=2, jj=7 → b.row(7)[2]
        assert_eq!(packed[2 * NR + 7], 702.0);
        // panel 1 covers columns 16..18; jj=1 → b.row(17)[0]
        assert_eq!(packed[3 * NR + 1], 1700.0);
        // padded columns 18..32 are zero across all kk
        for kk in 0..3 {
            for jj in 2..NR {
                assert_eq!(packed[(3 + kk) * NR + jj], 0.0);
            }
        }
    }

    #[test]
    fn packbuf_grows_monotonically_and_reuses() {
        let mut buf = PackBuf::new();
        let b_big = Mat::filled_with(20, 40, |r, c| (r + c) as f32);
        pack_nn(&mut buf, MatView::full(&b_big));
        let cap = buf.capacity_floats();
        let ptr = buf.as_ptr();
        assert!(cap >= 20 * 48);
        // a smaller pack must not shrink or reallocate
        let b_small = Mat::filled_with(2, 3, |_, _| 1.0);
        pack_nn(&mut buf, MatView::full(&b_small));
        assert_eq!(buf.capacity_floats(), cap);
        assert_eq!(buf.as_ptr(), ptr, "small pack reallocated the buffer");
    }

    #[test]
    fn gemm_chunk_writes_only_its_column_block() {
        // C is 5 wide, live block is cols [1, 4) — cols 0 and 4 untouched
        let a = Mat::filled_with(3, 2, |r, c| (r + c) as f32 + 1.0);
        let b = Mat::filled_with(2, 3, |r, c| (r * 3 + c) as f32);
        let mut buf = PackBuf::new();
        let packed = pack_nn(&mut buf, MatView::full(&b));
        let mut c = vec![7.0f32; 3 * 5];
        gemm_chunk(MatView::full(&a), 0, packed, 2, 3, &mut c, 5, 1);
        for i in 0..3 {
            assert_eq!(c[i * 5], 7.0, "col 0 clobbered");
            assert_eq!(c[i * 5 + 4], 7.0, "col 4 clobbered");
            for j in 0..3 {
                let want: f32 = (0..2)
                    .map(|kk| a.at(i, kk) * b.at(kk, j))
                    .sum();
                assert_eq!(c[i * 5 + 1 + j], want);
            }
        }
        // k == 0 zeroes the block (and only the block) even over garbage
        gemm_chunk(MatView::full(&a).first_cols(0), 0, &[], 0, 3, &mut c, 5, 1);
        for i in 0..3 {
            assert_eq!(c[i * 5], 7.0);
            assert_eq!(&c[i * 5 + 1..i * 5 + 4], &[0.0; 3]);
        }
    }

    #[test]
    fn i8x32_lane_ops() {
        // pair_splat: low NR lanes = lo, high NR lanes = hi
        let p = I8x32::pair_splat(3, -5);
        assert_eq!(&p.0[..NR], &[3i8; NR]);
        assert_eq!(&p.0[NR..], &[-5i8; NR]);
        // load/load_partial mirror the f32 lane semantics
        let src: Vec<i8> = (0..I8_LANES as i8).collect();
        assert_eq!(I8x32::load(&src).0[31], 31);
        let part = I8x32::load_partial(&src[..5]);
        assert_eq!(&part.0[..5], &[0, 1, 2, 3, 4]);
        assert_eq!(&part.0[5..], &[0i8; I8_LANES - 5]);
        assert_eq!(I8x32::load_partial(&[]).0, [0i8; I8_LANES]);
        // widening_mul_acc is exact at the i8 extremes: (-127)·(-127)
        // and 127·(-127) both fit the i16 intermediate without wrap
        let a = I8x32::pair_splat(-127, 127);
        let b = I8x32([-127i8; I8_LANES]);
        let mut acc = [1i32; I8_LANES];
        a.widening_mul_acc(b, &mut acc);
        assert_eq!(acc[0], 1 + 127 * 127);
        assert_eq!(acc[NR], 1 - 127 * 127);
        // accumulates on top of existing partials
        a.widening_mul_acc(b, &mut acc);
        assert_eq!(acc[0], 1 + 2 * 127 * 127);
    }

    #[test]
    fn pack_nn_i8_layout_scales_and_padding() {
        // column j has max |.| = 2 + j, so scale_j = (2 + j)/127 and the
        // max element quantizes to exactly ±127
        let b = Mat::filled_with(3, 5, |r, c| {
            if r == 1 { -((2 + c) as f32) } else { (c as f32) / 10.0 }
        });
        let mut buf = PackBufI8::new();
        let mut scales = Vec::new();
        let packed = pack_nn_i8(&mut buf, &mut scales, MatView::full(&b));
        assert_eq!(packed.len(), 3 * NR);
        assert_eq!(scales.len(), NR, "one scale slot per packed column");
        for j in 0..5 {
            assert_eq!(scales[j], (2 + j) as f32 / 127.0);
            assert_eq!(packed[NR + j], -127, "max element must hit -127");
        }
        // padding columns: zero scale, zero lanes
        for j in 5..NR {
            assert_eq!(scales[j], 0.0);
            for kk in 0..3 {
                assert_eq!(packed[kk * NR + j], 0);
            }
        }
        // an all-zero column dequantizes to exact zeros via scale 0
        let z = Mat::zeros(4, 2);
        let packed = pack_nn_i8(&mut buf, &mut scales, MatView::full(&z));
        assert_eq!(scales[0], 0.0);
        assert!(packed.iter().all(|&q| q == 0));
    }

    #[test]
    fn pack_nt_i8_per_row_channels() {
        // NT: output channel j is B row j; row 1 is all ±4
        let b = Mat::filled_with(3, 6, |r, c| {
            if r == 1 { if c % 2 == 0 { 4.0 } else { -4.0 } } else { 0.5 }
        });
        let mut buf = PackBufI8::new();
        let mut scales = Vec::new();
        let packed = pack_nt_i8(&mut buf, &mut scales, MatView::full(&b));
        assert_eq!(scales[1], 4.0 / 127.0);
        for kk in 0..6 {
            let want = if kk % 2 == 0 { 127 } else { -127 };
            assert_eq!(packed[kk * NR + 1], want);
        }
        // channel 0 is constant 0.5 → scale 0.5/127, every value 127
        assert_eq!(scales[0], 0.5 / 127.0);
        assert_eq!(packed[0], 127);
    }

    #[test]
    fn gemm_chunk_i8_matches_integer_reference() {
        let a = Mat::filled_with(7, 9, |r, c| ((r * 9 + c) as f32).sin());
        let b = Mat::filled_with(9, 19, |r, c| ((r * 19 + c) as f32).cos());
        let mut bbuf = PackBufI8::new();
        let mut scales = Vec::new();
        let packed = pack_nt_i8(
            &mut bbuf,
            &mut scales,
            MatView::full(&b.transpose()),
        );
        let mut abuf = PackBufI8::new();
        let (aq, a_scale) = quantize_activations(&mut abuf, MatView::full(&a));
        let mut c = vec![f32::NAN; 7 * 19];
        gemm_chunk_i8(aq, 0, packed, 9, 19, a_scale, &scales, &mut c, 19, 0);
        // replay the documented spec independently: exact i64 integer
        // accumulation over the same quantized operands, then the same
        // single-rounding dequant — must agree bitwise
        for i in 0..7 {
            for j in 0..19 {
                let mut acc = 0i64;
                for kk in 0..9 {
                    let qb = i64::from(packed[kk * NR + (j % NR)
                        + (j / NR) * 9 * NR]);
                    acc += i64::from(aq[i * 9 + kk]) * qb;
                }
                let want = acc as f32 * (a_scale * scales[j]);
                assert_eq!(
                    c[i * 19 + j].to_bits(),
                    want.to_bits(),
                    "({i},{j})"
                );
            }
        }
        // and the dequantized result approximates the f32 product
        for i in 0..7 {
            for j in 0..19 {
                let want: f32 =
                    (0..9).map(|kk| a.at(i, kk) * b.at(kk, j)).sum();
                assert!(
                    (c[i * 19 + j] - want).abs() < 0.15,
                    "({i},{j}): {} vs {}",
                    c[i * 19 + j],
                    want
                );
            }
        }
    }

    #[test]
    fn pack_a_layout_and_padding() {
        // 6×3 A → two MR-row panels, rows 6..8 zero-padded
        let a = Mat::filled_with(6, 3, |r, c| (r * 10 + c) as f32);
        let mut buf = PackBuf::new();
        let packed = pack_a(&mut buf, MatView::full(&a));
        assert_eq!(packed.len(), 2 * 3 * MR);
        // panel 0, kk=2, row 1 → a[1][2]
        assert_eq!(packed[2 * MR + 1], 12.0);
        // panel 1, kk=0, row 5 (local 1) → a[5][0]
        assert_eq!(packed[3 * MR + 1], 50.0);
        for kk in 0..3 {
            for ii in 2..MR {
                assert_eq!(packed[(3 + kk) * MR + ii], 0.0, "pad row");
            }
        }
    }

    #[test]
    fn gemm_chunk_pa_bitwise_matches_unpacked() {
        let a = Mat::filled_with(11, 23, |r, c| ((r * 31 + c * 7) as f32).sin());
        let b = Mat::filled_with(23, 21, |r, c| ((r + c * 3) as f32).cos());
        let mut bbuf = PackBuf::new();
        let packed = pack_nn(&mut bbuf, MatView::full(&b));
        let mut want = vec![0.0f32; 11 * 21];
        gemm_chunk(MatView::full(&a), 0, packed, 23, 21, &mut want, 21, 0);
        let mut abuf = PackBuf::new();
        let apack = pack_a(&mut abuf, MatView::full(&a));
        let mut got = vec![f32::NAN; 11 * 21];
        gemm_chunk_pa(apack, 0, packed, 23, 21, &mut got, 21, 0);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "elem {i}");
        }
        // an MR-aligned sub-chunk (rows 4..11) sees the same values
        let mut sub = vec![f32::NAN; 7 * 21];
        gemm_chunk_pa(apack, 4, packed, 23, 21, &mut sub, 21, 0);
        assert_eq!(&sub[..], &want[4 * 21..]);
    }
}
