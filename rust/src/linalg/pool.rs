//! Process-wide persistent compute pool — the single thread budget every
//! parallel hot path draws from.
//!
//! Before this module, each parallel region (`gemm` row partitions,
//! `encode_batch` example striping, every coordinator bucket worker's
//! batch) spawned its own `std::thread::scope` threads and planned against
//! the *whole* machine.  At serving concurrency that meant a thread spawn
//! per batch per GEMM and, worse, N concurrently-busy buckets each using
//! `gemm::max_threads()` workers — N-fold oversubscription.  The pool
//! replaces all of that:
//!
//! - **One set of workers.**  [`global()`] lazily spawns
//!   [`gemm::max_threads()`](super::gemm::max_threads) persistent workers
//!   (the process compute budget, set via `LINFORMER_THREADS` or
//!   [`gemm::set_max_threads`](super::gemm::set_max_threads) *before*
//!   first use).  They live for the process; there is no per-batch spawn
//!   or join cost.
//! - **A hard concurrency bound.**  Parallel tasks execute *only* on pool
//!   workers; a non-worker caller of [`Pool::run`] parks until its tasks
//!   finish instead of computing alongside them.  However many buckets,
//!   batches and GEMMs are in flight, at most `budget` threads do compute
//!   work at any instant (pinned by `concurrency_never_exceeds_workers`
//!   and the `pool_stress` integration test).  Work below the GEMM
//!   parallel threshold stays inline on the caller, exactly as before.
//! - **Determinism.**  The pool only changes *where* a task runs, never
//!   how work is partitioned: each task is the same serial kernel over the
//!   same chunk the scoped-thread path used, so outputs stay bitwise
//!   identical for any pool size (see `gemm::threaded_matches_serial_bitwise`).
//!
//! # Nesting and deadlock-freedom
//!
//! `encode_batch` tasks call back into `gemm`, which may submit nested
//! task sets.  A pool worker that waits on a nested set would deadlock if
//! it merely parked (all workers could end up waiting on queued tasks no
//! thread is left to run), so a *worker* waiting on [`Pool::run`] helps
//! drain the queue instead of sleeping.  Task sets form a strict DAG
//! (batch item → GEMM chunks, attention head → logits/context chunks;
//! chunks are leaves), so helping always makes progress and every `run`
//! returns.
//!
//! # Nested fan-out budget accounting
//!
//! A caller that fans out at two levels — batch items that each run
//! GEMMs, or attention heads that each run their per-head GEMM chain —
//! must not plan `outer × threads` worth of parallelism against a
//! `threads`-sized budget: the pool's hard bound keeps the *execution*
//! honest, but over-planning still queues far more fine-grained chunk
//! tasks than can ever run at once, paying queue traffic for no extra
//! concurrency.  [`split_budget`] is the one shared accounting rule:
//! give the outer level `min(threads, items)` lanes and each task the
//! integer share `threads / outer` for its nested GEMM plans, so
//! `outer · inner ≤ threads` always.  `encode_batch`'s batch-vs-GEMM
//! split and the encoder's head-vs-GEMM split both route through it.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// A unit of compute work borrowed from the caller's stack frame.
/// [`Pool::run`] guarantees every task has finished before it returns,
/// which is what makes the borrow sound.
pub type Task<'env> = Box<dyn FnOnce() + Send + 'env>;

type StaticTask = Box<dyn FnOnce() + Send + 'static>;

/// Completion state of one `run` call (one "scope" of tasks).
struct ScopeState {
    /// Tasks submitted but not yet finished executing.
    pending: AtomicUsize,
    /// Mutex/condvar pair the owner parks on until `pending` hits zero.
    done_mx: Mutex<()>,
    done_cv: Condvar,
    /// First panic payload raised by a task, re-raised on the owner.
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

struct QueuedTask {
    scope: Arc<ScopeState>,
    task: StaticTask,
    /// Submitted via [`Pool::spawn`] (no owner waiting).  Helpers skip
    /// these: a worker blocked on a few nested chunk tasks must not
    /// inline a whole detached serving batch (tens of ms) and couple its
    /// own caller's latency to another bucket's work.
    detached: bool,
}

struct Shared {
    queue: Mutex<VecDeque<QueuedTask>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    /// Tasks executing right now / the high-water mark — the budget
    /// instrumentation the stress test asserts against.
    busy: AtomicUsize,
    peak_busy: AtomicUsize,
}

/// A persistent worker pool.  Use [`global()`] everywhere except tests.
pub struct Pool {
    shared: Arc<Shared>,
    workers: usize,
}

std::thread_local! {
    /// Set on pool worker threads: a nested [`Pool::run`] from a worker
    /// helps drain the queue instead of parking (see module docs).
    static IS_POOL_WORKER: std::cell::Cell<bool> =
        const { std::cell::Cell::new(false) };

    /// Whether this thread is already inside [`execute`]: a worker that
    /// *helps* while blocked in a nested [`Pool::run`] re-enters
    /// `execute` on the same thread, and must not be counted in `busy` a
    /// second time — `busy` counts threads doing compute, not stack
    /// frames.
    static IN_TASK: std::cell::Cell<bool> =
        const { std::cell::Cell::new(false) };
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// Split a thread budget between an outer fan-out of `items` independent
/// tasks and the nested parallelism inside each task (see the module's
/// "Nested fan-out budget accounting" section).  Returns
/// `(outer, inner)`: the number of outer lanes to fan out and the thread
/// cap each lane passes to its nested GEMM plans.  Guarantees
/// `outer ≥ 1`, `inner ≥ 1` and `outer · inner ≤ max(threads, 1)`, so
/// stacked fan-outs never plan past the budget.  Purely an accounting
/// rule — it never changes how work is *partitioned*, only how many
/// chunk tasks get queued, so outputs stay bitwise identical for any
/// budget (pinned end-to-end by `tests/attn_prop.rs` and
/// `encode_batch_matches_looped_encode_bitwise`).
#[inline]
pub fn split_budget(threads: usize, items: usize) -> (usize, usize) {
    let outer = threads.min(items).max(1);
    let inner = (threads / outer).max(1);
    (outer, inner)
}

/// The process-wide pool, sized to [`super::gemm::max_threads()`] at first
/// use.  Call [`super::gemm::set_max_threads`] (or export
/// `LINFORMER_THREADS`) before any parallel work to change the budget.
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| Pool::new(super::gemm::max_threads()))
}

impl Pool {
    /// Spawn a pool with `workers` persistent threads (at least one).
    pub fn new(workers: usize) -> Pool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            busy: AtomicUsize::new(0),
            peak_busy: AtomicUsize::new(0),
        });
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("linformer-pool-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
        }
        Pool { shared, workers }
    }

    /// The compute-thread budget: number of persistent workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// High-water mark of concurrently-executing tasks since the pool
    /// started.  By construction this never exceeds [`Pool::workers`].
    pub fn peak_busy(&self) -> usize {
        self.shared.peak_busy.load(Ordering::Relaxed)
    }

    /// Execute every task and return once **all** of them have finished.
    ///
    /// Tasks may borrow from the caller's stack (they are `'env`, not
    /// `'static`); the blocking contract is what makes that sound.  A
    /// single-task set runs inline on the caller — it is the serial case
    /// and paying a queue round-trip for it would only add latency.  If a
    /// task panics, the panic is re-raised here after the remaining tasks
    /// finish.
    pub fn run<'env>(&self, tasks: Vec<Task<'env>>) {
        let mut tasks = tasks;
        if tasks.len() <= 1 {
            if let Some(task) = tasks.pop() {
                task();
            }
            return;
        }
        let scope = Arc::new(ScopeState {
            pending: AtomicUsize::new(tasks.len()),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.shared.queue.lock().expect("pool queue");
            for task in tasks {
                // SAFETY: this function does not return until `pending`
                // reaches zero, i.e. until every queued task has finished
                // executing, so the 'env borrows inside each task strictly
                // outlive every use.  The box is only ever called once.
                let task: StaticTask = unsafe { std::mem::transmute(task) };
                q.push_back(QueuedTask {
                    scope: Arc::clone(&scope),
                    task,
                    detached: false,
                });
            }
        }
        self.shared.work_cv.notify_all();

        let helping = IS_POOL_WORKER.with(|f| f.get());
        while scope.pending.load(Ordering::Acquire) != 0 {
            if helping {
                // A worker must not sleep while work is queued: the queued
                // tasks may be exactly the ones it is waiting for (or be
                // blocking the workers that hold them) — see module docs.
                // Detached tasks are skipped: they belong to no scope, so
                // they can never be what this worker waits on, and
                // inlining one would stall this scope for its full
                // duration.
                let next = {
                    let mut q =
                        self.shared.queue.lock().expect("pool queue");
                    q.iter()
                        .rposition(|t| !t.detached)
                        .and_then(|i| q.remove(i))
                };
                if let Some(qt) = next {
                    execute(&self.shared, qt);
                    continue;
                }
            }
            let guard = scope.done_mx.lock().expect("pool scope");
            if scope.pending.load(Ordering::Acquire) == 0 {
                break;
            }
            // Timeout as a missed-wakeup backstop; completion also
            // notifies, so the common path wakes immediately.
            let _ = scope
                .done_cv
                .wait_timeout(guard, Duration::from_millis(1))
                .expect("pool scope wait");
        }
        if let Some(payload) = scope.panic.lock().expect("pool panic").take() {
            resume_unwind(payload);
        }
    }

    /// Submit one detached `'static` task and return immediately.
    ///
    /// Unlike [`Pool::run`] nothing blocks on completion — the caller is
    /// responsible for its own completion signalling (the serving
    /// scheduler sends itself a message from inside the task).  A panic
    /// inside a detached task is caught and swallowed by the worker (there
    /// is no owner to re-raise it on); tasks that can fail should carry
    /// their own error channel.
    pub fn spawn(&self, task: impl FnOnce() + Send + 'static) {
        let scope = Arc::new(ScopeState {
            pending: AtomicUsize::new(1),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        self.shared.queue.lock().expect("pool queue").push_back(
            QueuedTask { scope, task: Box::new(task), detached: true },
        );
        self.shared.work_cv.notify_one();
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // Only test pools are ever dropped (the global pool lives for the
        // process): signal workers so their threads exit once idle.
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
    }
}

fn worker_loop(shared: &Shared) {
    IS_POOL_WORKER.with(|f| f.set(true));
    loop {
        let next = {
            let mut q = shared.queue.lock().expect("pool queue");
            loop {
                if let Some(qt) = q.pop_front() {
                    break Some(qt);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.work_cv.wait(q).expect("pool wait");
            }
        };
        match next {
            Some(qt) => execute(shared, qt),
            None => return,
        }
    }
}

/// Run one task, maintain the busy instrumentation, record any panic and
/// signal the owning scope when its last task finishes.  The busy count
/// is per *thread*, not per stack frame: a helping worker re-entering
/// here from a nested wait is already counted by its outermost frame.
fn execute(shared: &Shared, qt: QueuedTask) {
    let QueuedTask { scope, task, .. } = qt;
    let outermost = IN_TASK.with(|f| !f.replace(true));
    if outermost {
        let now = shared.busy.fetch_add(1, Ordering::AcqRel) + 1;
        shared.peak_busy.fetch_max(now, Ordering::AcqRel);
    }
    let result = catch_unwind(AssertUnwindSafe(task));
    if outermost {
        shared.busy.fetch_sub(1, Ordering::AcqRel);
        IN_TASK.with(|f| f.set(false));
    }
    if let Err(payload) = result {
        let mut slot = scope.panic.lock().expect("pool panic");
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
    if scope.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        // last task: wake the owner (lock pairs with the owner's
        // check-then-wait so the notify cannot be missed)
        let _guard = scope.done_mx.lock().expect("pool scope");
        scope.done_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::SeqCst;

    #[test]
    fn run_executes_every_task_exactly_once() {
        let pool = Pool::new(3);
        let counts: Vec<AtomicUsize> =
            (0..64).map(|_| AtomicUsize::new(0)).collect();
        let tasks: Vec<Task<'_>> = counts
            .iter()
            .map(|c| {
                Box::new(move || {
                    c.fetch_add(1, SeqCst);
                }) as Task<'_>
            })
            .collect();
        pool.run(tasks);
        assert!(counts.iter().all(|c| c.load(SeqCst) == 1));
    }

    #[test]
    fn empty_and_single_task_sets_run_inline() {
        let pool = Pool::new(2);
        pool.run(Vec::new());
        let hit = AtomicUsize::new(0);
        let hit_r = &hit;
        pool.run(vec![Box::new(move || {
            hit_r.fetch_add(1, SeqCst);
        }) as Task<'_>]);
        assert_eq!(hit.load(SeqCst), 1);
    }

    #[test]
    fn concurrency_never_exceeds_workers() {
        let pool = Pool::new(2);
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let (live_r, peak_r) = (&live, &peak);
        let tasks: Vec<Task<'_>> = (0..32)
            .map(|_| {
                Box::new(move || {
                    let now = live_r.fetch_add(1, SeqCst) + 1;
                    peak_r.fetch_max(now, SeqCst);
                    std::thread::sleep(Duration::from_millis(2));
                    live_r.fetch_sub(1, SeqCst);
                }) as Task<'_>
            })
            .collect();
        pool.run(tasks);
        assert!(peak.load(SeqCst) >= 1);
        assert!(
            peak.load(SeqCst) <= 2,
            "budget exceeded: {} tasks ran concurrently on a 2-worker pool",
            peak.load(SeqCst)
        );
        assert!(pool.peak_busy() <= 2);
    }

    #[test]
    fn nested_run_from_workers_completes() {
        let pool = Pool::new(2);
        let sum = AtomicUsize::new(0);
        let (sum_r, pool_r) = (&sum, &pool);
        let outer: Vec<Task<'_>> = (0..4)
            .map(|i| {
                Box::new(move || {
                    let inner: Vec<Task<'_>> = (0..4)
                        .map(|j| {
                            Box::new(move || {
                                sum_r.fetch_add(100 * i + j, SeqCst);
                            }) as Task<'_>
                        })
                        .collect();
                    pool_r.run(inner);
                }) as Task<'_>
            })
            .collect();
        pool.run(outer);
        let want: usize =
            (0..4).map(|i| (0..4).map(|j| 100 * i + j).sum::<usize>()).sum();
        assert_eq!(sum.load(SeqCst), want);
        // a worker helping inside a nested run is one busy thread, not
        // two — the budget instrumentation must not double-count it
        assert!(
            pool.peak_busy() <= 2,
            "nested helping double-counted: peak {} on 2 workers",
            pool.peak_busy()
        );
    }

    #[test]
    fn parallel_callers_share_one_pool() {
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        let (total_r, pool_r) = (&total, &pool);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(move || {
                    for _ in 0..8 {
                        let tasks: Vec<Task<'_>> = (0..3)
                            .map(|_| {
                                Box::new(move || {
                                    total_r.fetch_add(1, SeqCst);
                                })
                                    as Task<'_>
                            })
                            .collect();
                        pool_r.run(tasks);
                    }
                });
            }
        });
        assert_eq!(total.load(SeqCst), 4 * 8 * 3);
        assert!(pool.peak_busy() <= 2, "peak {} > 2", pool.peak_busy());
    }

    #[test]
    fn spawn_runs_detached_tasks() {
        let pool = Pool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..16usize {
            let tx = tx.clone();
            pool.spawn(move || {
                let _ = tx.send(i);
            });
        }
        let mut got: Vec<usize> = (0..16)
            .map(|_| rx.recv_timeout(Duration::from_secs(5)).unwrap())
            .collect();
        got.sort_unstable();
        assert_eq!(got, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn spawned_task_can_nest_blocking_runs() {
        // a detached task that fans out a nested task set (exactly what a
        // dispatched serving batch does via encode_batch) must complete
        // even on a single-worker pool — the worker helps drain
        let pool: &'static Pool = Box::leak(Box::new(Pool::new(1)));
        let (tx, rx) = std::sync::mpsc::channel();
        let sum = Arc::new(AtomicUsize::new(0));
        let sum2 = Arc::clone(&sum);
        pool.spawn(move || {
            let tasks: Vec<Task<'_>> = (0..8)
                .map(|i| {
                    let s = Arc::clone(&sum2);
                    Box::new(move || {
                        s.fetch_add(i, SeqCst);
                    }) as Task<'_>
                })
                .collect();
            pool.run(tasks);
            let _ = tx.send(());
        });
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(sum.load(SeqCst), (0..8).sum::<usize>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn task_panic_propagates_to_owner() {
        let pool = Pool::new(2);
        let tasks: Vec<Task<'static>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("boom")),
            Box::new(|| {}),
        ];
        pool.run(tasks);
    }

    #[test]
    fn split_budget_never_overplans() {
        // outer·inner ≤ budget, both at least 1, for every combination
        for threads in 0..=17usize {
            for items in 0..=9usize {
                let (outer, inner) = split_budget(threads, items);
                assert!(outer >= 1 && inner >= 1, "t={threads} i={items}");
                assert!(
                    outer * inner <= threads.max(1),
                    "t={threads} i={items}: {outer}×{inner} over budget"
                );
                assert!(outer <= items.max(1), "more lanes than items");
            }
        }
        // the documented splits: 8 threads over 2 heads → 2 lanes of 4;
        // 2 threads over 8 items → 2 lanes of 1; serial stays serial
        assert_eq!(split_budget(8, 2), (2, 4));
        assert_eq!(split_budget(2, 8), (2, 1));
        assert_eq!(split_budget(1, 8), (1, 1));
    }

    #[test]
    fn global_pool_is_sized_to_the_budget() {
        let p = global();
        assert_eq!(p.workers(), crate::linalg::gemm::max_threads());
        assert!(p.peak_busy() <= p.workers());
    }
}
