//! From-scratch dense linear algebra kit (f32, row-major).
//!
//! Used by (a) the pure-Rust reference transformer in [`crate::model`]
//! (the CPU baseline independent of XLA), (b) the Fig 1 spectrum analysis
//! (SVD of attention matrices), and (c) assorted substrates.  Not intended
//! to compete with BLAS — but the gemm runs an explicit SIMD-width-aware
//! register-tiled microkernel over packed B panels (see [`kernel`] and
//! [`gemm`]) and is multi-threaded, so the Rust baseline is compute-
//! rather than overhead-bound, and [`MatView`] gives zero-copy strided access to
//! sub-matrices (per-head Q/K/V slices, parameter tensors, sliced E/F
//! projections) so the encoder hot path never copies its inputs.  All
//! parallel work executes on the persistent process-wide [`pool`], which
//! caps compute at one global thread budget however many callers are in
//! flight.

pub mod gemm;
pub mod kernel;
pub mod pool;
pub mod svd;

pub use gemm::{
    matmul, matmul_into, matmul_nt, matmul_nt_into, Dtype, PackedPanels,
};

use kernel::{F32x8, LANES};

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn filled_with(
        rows: usize,
        cols: usize,
        mut f: impl FnMut(usize, usize) -> f32,
    ) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// out = self + other (elementwise).
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Broadcast-add a row vector to every row.  Delegates to the
    /// lane-vectorized slice core [`bias_rows`] — the same code the
    /// fused GEMM epilogues run per row chunk, so standalone and fused
    /// bias adds are bitwise identical.
    pub fn add_row_vec(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        let cols = self.cols;
        bias_rows(&mut self.data, cols, bias);
    }

    /// Reshape in place to (rows × cols), zero-filled.  Reuses the
    /// existing allocation whenever capacity suffices — the contract the
    /// encoder scratch buffers rely on for an allocation-free hot path.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Reshape in place **without** zeroing surviving elements — for
    /// callers that provably overwrite every element before reading it
    /// (the SIMD GEMM entry points, whose first-k-block tiles start
    /// their accumulators at zero instead of loading C).  Elements the
    /// buffer grows by are still zero; stale values can only remain in
    /// the reused prefix, which the caller must fully store over.
    pub fn resize_for_overwrite(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Become a copy of `src`, reusing the existing allocation.
    pub fn copy_from(&mut self, src: &Mat) {
        self.rows = src.rows;
        self.cols = src.cols;
        self.data.clear();
        self.data.extend_from_slice(&src.data);
    }
}

/// Borrowed, read-only view of a row-major matrix with an arbitrary row
/// stride — the zero-copy counterpart of [`Mat`].
///
/// A view can window any column range of a wider matrix (a per-head slice
/// of packed Q/K/V, the first `n` columns of a (k × max_len) projection)
/// without materialising it; the [`gemm`] kernels consume views directly.
#[derive(Debug, Clone, Copy)]
pub struct MatView<'a> {
    data: &'a [f32],
    pub rows: usize,
    pub cols: usize,
    stride: usize,
}

impl<'a> MatView<'a> {
    /// View over raw storage: row `r` is `data[r*stride .. r*stride+cols]`.
    pub fn new(data: &'a [f32], rows: usize, cols: usize, stride: usize) -> Self {
        assert!(cols <= stride || rows <= 1, "view cols exceed stride");
        if rows > 0 {
            let need = (rows - 1) * stride + cols;
            assert!(need <= data.len(), "view out of bounds: {need} > {}", data.len());
        }
        MatView { data, rows, cols, stride }
    }

    /// The whole of `m`, as a view.
    pub fn full(m: &'a Mat) -> Self {
        Self::new(&m.data, m.rows, m.cols, m.cols)
    }

    /// Columns `[col0, col0 + cols)` of `m` — a strided window, no copy.
    pub fn cols(m: &'a Mat, col0: usize, cols: usize) -> Self {
        assert!(col0 + cols <= m.cols, "column window out of range");
        if m.rows == 0 {
            return Self::new(&[], 0, cols, cols.max(1));
        }
        Self::new(&m.data[col0..], m.rows, cols, m.cols)
    }

    /// Restrict the view to its first `n` columns (stride unchanged) —
    /// how a (k × max_len) E/F projection is sliced to a live length.
    pub fn first_cols(mut self, n: usize) -> Self {
        assert!(n <= self.cols, "first_cols out of range");
        self.cols = n;
        self
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.stride..r * self.stride + self.cols]
    }

    /// Materialise the view as an owned [`Mat`] (tests / capture only).
    pub fn to_mat(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            m.row_mut(r).copy_from_slice(self.row(r));
        }
        m
    }
}

/// Numerically-stable in-place row softmax.
///
/// A **fully-masked row** (every logit `-inf`, e.g. an empty or wholly
/// padded attention slice) is defined to produce the **uniform**
/// distribution `1/n` — the same output as an all-zero logit row.
/// Without the guard, `max = -inf` makes every shifted logit
/// `-inf - -inf = NaN`, the row sum `0·NaN`, and the normalised row all
/// NaN — which then poisons every downstream matmul.  Uniform keeps the
/// "rows are stochastic" invariant the attention tests pin, and bounds
/// the downstream context at the mean of V instead of corrupting it.
pub fn softmax_rows(m: &mut Mat) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        if max == f32::NEG_INFINITY {
            // `f32::max` ignores NaN, so an all-NaN (or NaN + -inf) row
            // also lands here — that is upstream *corruption*, not a
            // mask, and must keep propagating as NaN (the same
            // invariant the gemm's no-zero-skip rule pins).  Only a
            // genuinely all--inf (or empty) row takes the uniform exit.
            if row.iter().all(|x| *x == f32::NEG_INFINITY) {
                let inv = 1.0 / row.len() as f32;
                row.fill(inv);
                continue;
            }
        }
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        let inv = 1.0 / sum;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// In-place row softmax of `mat · scale`, with the scalar multiply
/// **folded into the max pass**: one sweep writes `x * scale` back and
/// tracks the running max of the scaled values, where the two-pass form
/// (`Mat::scale` then [`softmax_rows`]) streams the whole matrix twice.
///
/// Bitwise-identical to `m.scale(scale); softmax_rows(&mut m)`: the
/// per-element multiply is the same single operation either way, the max
/// scan visits elements in the same order with the same `f32::max`, and
/// the exp/normalize passes are unchanged — including the fully-masked
/// uniform guard and NaN propagation documented on [`softmax_rows`]
/// (`-inf * scale` stays `-inf` for the positive scales attention uses,
/// and a NaN row stays NaN).  Pinned by
/// `softmax_scaled_matches_scale_then_softmax_bitwise`.
///
/// This is the attention epilogue: the fused GEMM entry point
/// ([`gemm::matmul_nt_softmax_view_in`]) applies the same slice-level
/// core ([`softmax_scaled_slice_rows`]) per row chunk, so fused and
/// standalone results are the same code over the same rows.
pub fn softmax_scaled_rows(m: &mut Mat, scale: f32) {
    let cols = m.cols;
    softmax_scaled_slice_rows(&mut m.data, cols, scale);
}

/// Slice-level core of [`softmax_scaled_rows`]: `data` is a whole number
/// of `cols`-wide rows (any row range of a row-major matrix whose width
/// equals its stride).  The GEMM row-chunk epilogue calls this on each
/// chunk — chunks partition the row set and softmax is per-row, so the
/// result is independent of the chunking.
pub fn softmax_scaled_slice_rows(data: &mut [f32], cols: usize, scale: f32) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(data.len() % cols, 0, "partial row handed to softmax");
    for row in data.chunks_mut(cols) {
        let mut max = f32::NEG_INFINITY;
        for x in row.iter_mut() {
            *x *= scale;
            max = max.max(*x);
        }
        if max == f32::NEG_INFINITY {
            // same contract as `softmax_rows`: only a genuinely all--inf
            // row takes the uniform exit; NaN keeps propagating
            if row.iter().all(|x| *x == f32::NEG_INFINITY) {
                let inv = 1.0 / row.len() as f32;
                row.fill(inv);
                continue;
            }
        }
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        let inv = 1.0 / sum;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// Row-wise layer norm with learned scale/bias.  Delegates to the
/// lane-vectorized slice core [`layer_norm_slice_rows`] shared with the
/// fused GEMM epilogues.
pub fn layer_norm_rows(m: &mut Mat, scale: &[f32], bias: &[f32], eps: f32) {
    assert_eq!(scale.len(), m.cols);
    assert_eq!(bias.len(), m.cols);
    let cols = m.cols;
    layer_norm_slice_rows(&mut m.data, cols, scale, bias, eps);
}

/// tanh-approximation GELU (matches the L2 jax model).  Delegates to the
/// lane-vectorized slice core [`gelu_rows`] shared with the fused GEMM
/// epilogues.
pub fn gelu_inplace(m: &mut Mat) {
    let cols = m.cols;
    gelu_rows(&mut m.data, cols);
}

// ---------------------------------------------------------------------------
// Fused row primitives.
//
// Every elementwise pass the encoder runs after a GEMM — bias add, GELU,
// residual accumulate, layer norm — is expressed here as a slice-level
// core over a whole number of `cols`-wide rows, exactly like
// [`softmax_scaled_slice_rows`].  The generalized GEMM epilogue hook
// (see `gemm::matmul_epilogue_view_in` and friends) calls these cores on
// each row chunk while it is still cache-hot; the standalone fallbacks
// (`Mat::add_row_vec`, `gelu_inplace`, `layer_norm_rows`, and the
// pool-striped variants in the encoder) call the *same* cores over the
// same rows.  Because chunks are whole rows and every core below is pure
// per-row (lane blocks are aligned to row starts, never straddling a row
// boundary), fused and unfused results are bitwise identical for any
// chunking, thread count, or kernel — the PR 8 invariant, generalized.
//
// Lane vectorization uses `F32x8::add`/`mul` only — never `mul_add` —
// so results are identical with and without the `fma` feature and the
// repro-lint R4 fence stays trivially satisfied outside the kernel.
// ---------------------------------------------------------------------------

/// One row: `row[j] += bias[j]`.
#[inline]
fn bias_row(row: &mut [f32], bias: &[f32]) {
    debug_assert_eq!(row.len(), bias.len());
    let mut blocks = row.chunks_exact_mut(LANES);
    let mut bblocks = bias.chunks_exact(LANES);
    for (blk, bb) in (&mut blocks).zip(&mut bblocks) {
        F32x8::load(blk).add(F32x8::load(bb)).store(blk);
    }
    for (x, b) in blocks.into_remainder().iter_mut().zip(bblocks.remainder())
    {
        *x += b;
    }
}

/// One row: tanh-approximation GELU in place.  The cubic and the outer
/// blend are lane ops; `tanh` itself has no lane form, so the inner
/// argument round-trips through a stack buffer for the libm call.
#[inline]
fn gelu_row(row: &mut [f32]) {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    let c = F32x8::splat(C);
    let k = F32x8::splat(0.044715);
    let half = F32x8::splat(0.5);
    let one = F32x8::splat(1.0);
    let mut blocks = row.chunks_exact_mut(LANES);
    for blk in &mut blocks {
        let v = F32x8::load(blk);
        let v3 = v.mul(v).mul(v);
        let inner = c.mul(v.add(k.mul(v3)));
        let mut t = [0.0f32; LANES];
        inner.store(&mut t);
        for e in &mut t {
            *e = e.tanh();
        }
        half.mul(v).mul(one.add(F32x8::load(&t))).store(blk);
    }
    for x in blocks.into_remainder() {
        let v = *x;
        let v3 = v * v * v;
        *x = 0.5 * v * (1.0 + (C * (v + 0.044715 * v3)).tanh());
    }
}

/// Mean and `1/sqrt(var + eps)` of one row — the shared reduction both
/// layer-norm forms (in-place and into) use, so their statistics are the
/// same bits.  Lane blocks accumulate eight partial sums which `hsum`
/// folds in a fixed order; the tail adds scalarly after.
#[inline]
fn ln_stats(row: &[f32], eps: f32) -> (f32, f32) {
    let n = row.len() as f32;
    let blocks = row.chunks_exact(LANES);
    let tail = blocks.remainder();
    let mut acc = F32x8::ZERO;
    for blk in blocks.clone() {
        acc = acc.add(F32x8::load(blk));
    }
    let mut sum = acc.hsum();
    for &x in tail {
        sum += x;
    }
    let mean = sum / n;
    let neg_mean = F32x8::splat(-mean);
    let mut vacc = F32x8::ZERO;
    for blk in blocks {
        let d = F32x8::load(blk).add(neg_mean);
        vacc = vacc.add(d.mul(d));
    }
    let mut var = vacc.hsum();
    for &x in tail {
        let d = x - mean;
        var += d * d;
    }
    var /= n;
    (mean, 1.0 / (var + eps).sqrt())
}

/// One row: layer norm in place with learned scale/bias.
#[inline]
fn ln_row(row: &mut [f32], scale: &[f32], bias: &[f32], eps: f32) {
    let (mean, inv) = ln_stats(row, eps);
    let neg_mean = F32x8::splat(-mean);
    let inv_v = F32x8::splat(inv);
    let mut blocks = row.chunks_exact_mut(LANES);
    let mut sb = scale.chunks_exact(LANES);
    let mut bb = bias.chunks_exact(LANES);
    for ((blk, s), b) in (&mut blocks).zip(&mut sb).zip(&mut bb) {
        let xm = F32x8::load(blk).add(neg_mean);
        xm.mul(inv_v).mul(F32x8::load(s)).add(F32x8::load(b)).store(blk);
    }
    for ((x, s), b) in blocks
        .into_remainder()
        .iter_mut()
        .zip(sb.remainder())
        .zip(bb.remainder())
    {
        *x = (*x - mean) * inv * s + b;
    }
}

/// One row: `dst = layer_norm(src)` — the copy and the normalize in a
/// single pass, replacing `copy_from` + `layer_norm_rows`.  Statistics
/// come from [`ln_stats`], so the output matches the in-place form bit
/// for bit.
#[inline]
fn ln_row_into(dst: &mut [f32], src: &[f32], scale: &[f32], bias: &[f32], eps: f32) {
    debug_assert_eq!(dst.len(), src.len());
    let (mean, inv) = ln_stats(src, eps);
    let neg_mean = F32x8::splat(-mean);
    let inv_v = F32x8::splat(inv);
    let mut dblocks = dst.chunks_exact_mut(LANES);
    let mut sblocks = src.chunks_exact(LANES);
    let mut sb = scale.chunks_exact(LANES);
    let mut bb = bias.chunks_exact(LANES);
    for (((d, x), s), b) in
        (&mut dblocks).zip(&mut sblocks).zip(&mut sb).zip(&mut bb)
    {
        let xm = F32x8::load(x).add(neg_mean);
        xm.mul(inv_v).mul(F32x8::load(s)).add(F32x8::load(b)).store(d);
    }
    for (((d, x), s), b) in dblocks
        .into_remainder()
        .iter_mut()
        .zip(sblocks.remainder())
        .zip(sb.remainder())
        .zip(bb.remainder())
    {
        *d = (*x - mean) * inv * s + b;
    }
}

/// Slice core: `data[r][j] += bias[j]` over whole rows.
pub fn bias_rows(data: &mut [f32], cols: usize, bias: &[f32]) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(data.len() % cols, 0, "partial row handed to bias_rows");
    debug_assert_eq!(bias.len(), cols);
    for row in data.chunks_mut(cols) {
        bias_row(row, bias);
    }
}

/// Slice core: GELU in place over whole rows.
pub fn gelu_rows(data: &mut [f32], cols: usize) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(data.len() % cols, 0, "partial row handed to gelu_rows");
    for row in data.chunks_mut(cols) {
        gelu_row(row);
    }
}

/// Slice core: bias add then GELU over whole rows — the FFN
/// up-projection epilogue.  Each row gets the same two sweeps the
/// standalone `add_row_vec` + `gelu_inplace` pair runs, just while the
/// row is cache-hot.
pub fn bias_gelu_rows(data: &mut [f32], cols: usize, bias: &[f32]) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(data.len() % cols, 0, "partial row handed to bias_gelu_rows");
    debug_assert_eq!(bias.len(), cols);
    for row in data.chunks_mut(cols) {
        bias_row(row, bias);
        gelu_row(row);
    }
}

/// Slice core: layer norm over whole rows with learned scale/bias.
pub fn layer_norm_slice_rows(
    data: &mut [f32],
    cols: usize,
    scale: &[f32],
    bias: &[f32],
    eps: f32,
) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(data.len() % cols, 0, "partial row handed to layer norm");
    debug_assert_eq!(scale.len(), cols);
    debug_assert_eq!(bias.len(), cols);
    for row in data.chunks_mut(cols) {
        ln_row(row, scale, bias, eps);
    }
}

/// Slice core: `dst = layer_norm(src)` over whole rows — one pass where
/// `copy_from` + `layer_norm_rows` took two.
pub fn layer_norm_rows_into(
    dst: &mut [f32],
    src: &[f32],
    cols: usize,
    scale: &[f32],
    bias: &[f32],
    eps: f32,
) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(dst.len(), src.len());
    debug_assert_eq!(dst.len() % cols, 0, "partial row handed to layer norm");
    for (d, s) in dst.chunks_mut(cols).zip(src.chunks(cols)) {
        ln_row_into(d, s, scale, bias, eps);
    }
}

/// Slice core: bias + GELU + layer norm over whole rows — the
/// `mlm_dense` head epilogue (`W·h + b` → GELU → LN in one visit).
pub fn bias_gelu_ln_rows(
    data: &mut [f32],
    cols: usize,
    bias: &[f32],
    ln_scale: &[f32],
    ln_bias: &[f32],
    eps: f32,
) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(data.len() % cols, 0, "partial row handed to bias_gelu_ln");
    for row in data.chunks_mut(cols) {
        bias_row(row, bias);
        gelu_row(row);
        ln_row(row, ln_scale, ln_bias, eps);
    }
}

/// Slice core of the residual epilogue: per row,
/// `x[j] += c[j] + bias[j]` then `h = layer_norm(x)` — the new residual
/// stream and the pre-normalized input of the *next* block, produced in
/// one visit while the GEMM output row `c` is cache-hot.  `c`, `x`, and
/// `h` are the same row range of three equal-width buffers.
///
/// Per-element arithmetic matches the standalone three-pass form
/// (`add_row_vec` rounds `c + bias` once, `add_assign` adds it to `x`,
/// `copy_from` + `layer_norm_rows` normalizes) bit for bit: the fused
/// form performs the identical operations in the identical order on each
/// element, it just never re-streams the buffers.
pub fn bias_residual_ln_rows(
    c: &[f32],
    x: &mut [f32],
    h: &mut [f32],
    cols: usize,
    bias: &[f32],
    ln_scale: &[f32],
    ln_bias: &[f32],
    eps: f32,
) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(c.len(), x.len());
    debug_assert_eq!(c.len(), h.len());
    debug_assert_eq!(c.len() % cols, 0, "partial row handed to residual_ln");
    for ((crow, xrow), hrow) in
        c.chunks(cols).zip(x.chunks_mut(cols)).zip(h.chunks_mut(cols))
    {
        bias_residual_row(crow, xrow, bias);
        ln_row_into(hrow, xrow, ln_scale, ln_bias, eps);
    }
}

/// Final-layer flavour of [`bias_residual_ln_rows`]: the residual stream
/// is not needed after the encoder's last block, so the layer norm lands
/// in place on `x` (`x = layer_norm(x + c + bias)`) and no `h` buffer is
/// written.
pub fn bias_residual_ln_inplace_rows(
    c: &[f32],
    x: &mut [f32],
    cols: usize,
    bias: &[f32],
    ln_scale: &[f32],
    ln_bias: &[f32],
    eps: f32,
) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(c.len(), x.len());
    debug_assert_eq!(c.len() % cols, 0, "partial row handed to residual_ln");
    for (crow, xrow) in c.chunks(cols).zip(x.chunks_mut(cols)) {
        bias_residual_row(crow, xrow, bias);
        ln_row(xrow, ln_scale, ln_bias, eps);
    }
}

/// Residual-only flavour: `x[j] += c[j] + bias[j]`, no norm — used when
/// the block's successor is not a layer norm (epilogue-fusion off keeps
/// this path too).
pub fn bias_residual_rows(c: &[f32], x: &mut [f32], cols: usize, bias: &[f32]) {
    if cols == 0 {
        return;
    }
    debug_assert_eq!(c.len(), x.len());
    debug_assert_eq!(c.len() % cols, 0, "partial row handed to residual");
    for (crow, xrow) in c.chunks(cols).zip(x.chunks_mut(cols)) {
        bias_residual_row(crow, xrow, bias);
    }
}

/// One row: `x[j] += c[j] + bias[j]`, with `c + bias` rounded before the
/// accumulate exactly as the two-pass form does.
#[inline]
fn bias_residual_row(c: &[f32], x: &mut [f32], bias: &[f32]) {
    debug_assert_eq!(c.len(), x.len());
    debug_assert_eq!(c.len(), bias.len());
    let mut xb = x.chunks_exact_mut(LANES);
    let mut cb = c.chunks_exact(LANES);
    let mut bb = bias.chunks_exact(LANES);
    for ((xx, cc), bv) in (&mut xb).zip(&mut cb).zip(&mut bb) {
        let t = F32x8::load(cc).add(F32x8::load(bv));
        F32x8::load(xx).add(t).store(xx);
    }
    for ((xx, cc), bv) in xb
        .into_remainder()
        .iter_mut()
        .zip(cb.remainder())
        .zip(bb.remainder())
    {
        *xx += cc + bv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::filled_with(3, 5, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(4, 2), m.at(2, 4));
    }

    #[test]
    fn softmax_rows_sum_to_one_and_stable() {
        let mut m = Mat::from_vec(2, 3, vec![1e4, 1e4, 1e4, 0.0, 1.0, 2.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(r).iter().all(|x| x.is_finite()));
        }
        assert!((m.at(0, 0) - 1.0 / 3.0).abs() < 1e-5);
        assert!(m.at(1, 2) > m.at(1, 1) && m.at(1, 1) > m.at(1, 0));
    }

    #[test]
    fn softmax_fully_masked_row_is_uniform() {
        // an all--inf row used to become sum == 0 → inv = inf → NaN row;
        // it must yield the documented uniform distribution instead, and
        // leave neighbouring rows untouched
        let ninf = f32::NEG_INFINITY;
        let mut m = Mat::from_vec(
            3,
            4,
            vec![
                0.0, 1.0, 2.0, 3.0, // normal row
                ninf, ninf, ninf, ninf, // fully masked
                ninf, ninf, 5.0, ninf, // partially masked
            ],
        );
        softmax_rows(&mut m);
        assert!(m.data.iter().all(|x| x.is_finite()), "NaN leaked: {m:?}");
        assert_eq!(m.row(1), &[0.25; 4], "masked row must be uniform");
        for r in [0usize, 2] {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sum {s}");
        }
        // a partially masked row puts all mass on the live logit
        assert!((m.at(2, 2) - 1.0).abs() < 1e-6);
        assert_eq!(m.at(2, 0), 0.0);
    }

    #[test]
    fn softmax_does_not_launder_nan_rows() {
        // f32::max ignores NaN, so an all-NaN row also sees max == -inf;
        // it must stay NaN (upstream corruption has to surface), never
        // become a plausible-looking uniform distribution
        let ninf = f32::NEG_INFINITY;
        let mut m = Mat::from_vec(
            2,
            3,
            vec![f32::NAN, f32::NAN, f32::NAN, f32::NAN, ninf, ninf],
        );
        softmax_rows(&mut m);
        assert!(m.row(0).iter().all(|x| x.is_nan()), "NaN laundered: {m:?}");
        assert!(m.row(1).iter().any(|x| x.is_nan()), "NaN laundered: {m:?}");
    }

    #[test]
    fn softmax_scaled_matches_scale_then_softmax_bitwise() {
        // the fused scale+softmax must be indistinguishable down to the
        // last bit from the two-pass form it replaces, including on
        // masked (-inf) and mixed rows
        let ninf = f32::NEG_INFINITY;
        let vals = vec![
            1e4, -1e4, 3.25, -0.5, //
            ninf, ninf, ninf, ninf, //
            ninf, 2.0, ninf, -7.5, //
            0.0, 0.0, 0.0, 0.0,
        ];
        for scale in [0.125f32, 1.0, 0.176_776_7 /* 1/sqrt(32) */] {
            let mut fused = Mat::from_vec(4, 4, vals.clone());
            let mut two_pass = fused.clone();
            softmax_scaled_rows(&mut fused, scale);
            two_pass.scale(scale);
            softmax_rows(&mut two_pass);
            for (a, b) in fused.data.iter().zip(&two_pass.data) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "fused softmax diverged at scale {scale}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn softmax_scaled_masked_row_is_uniform_and_nan_propagates() {
        let ninf = f32::NEG_INFINITY;
        let mut m = Mat::from_vec(
            2,
            3,
            vec![ninf, ninf, ninf, f32::NAN, 1.0, ninf],
        );
        softmax_scaled_rows(&mut m, 0.5);
        assert_eq!(m.row(0), &[1.0 / 3.0; 3], "masked row must be uniform");
        assert!(
            m.row(1).iter().any(|x| x.is_nan()),
            "NaN laundered: {m:?}"
        );
    }

    #[test]
    fn softmax_scaled_slice_rows_is_chunking_invariant() {
        // per-row softmax applied chunk-by-chunk must equal one whole-
        // matrix call for any partition into whole rows — the property
        // the GEMM epilogue's bitwise thread-invariance stands on
        let mut whole = Mat::filled_with(6, 5, |r, c| {
            ((r * 31 + c * 17) % 13) as f32 - 6.0
        });
        let raw = whole.clone();
        softmax_scaled_rows(&mut whole, 0.25);
        let cols = raw.cols;
        for rows in [&[1usize, 2, 3][..], &[4, 2], &[6]] {
            let mut redo = raw.clone();
            let mut rest = &mut redo.data[..];
            for &nr in rows {
                let (head, tail) = rest.split_at_mut(nr * cols);
                softmax_scaled_slice_rows(head, cols, 0.25);
                rest = tail;
            }
            for (a, b) in redo.data.iter().zip(&whole.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "chunking changed bits");
            }
        }
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut m = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        layer_norm_rows(&mut m, &[1.0; 4], &[0.0; 4], 1e-6);
        let mean: f32 = m.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 =
            m.row(0).iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_known_values() {
        let mut m = Mat::from_vec(1, 3, vec![-1.0, 0.0, 1.0]);
        gelu_inplace(&mut m);
        assert!((m.at(0, 1)).abs() < 1e-7);
        assert!((m.at(0, 2) - 0.841_192).abs() < 1e-3);
        assert!((m.at(0, 0) + 0.158_808).abs() < 1e-3);
    }

    #[test]
    fn add_row_vec_broadcasts() {
        let mut m = Mat::zeros(2, 3);
        m.add_row_vec(&[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn from_vec_validates_len() {
        Mat::from_vec(2, 2, vec![0.0; 3]);
    }

    #[test]
    fn reset_reuses_allocation_and_zeroes() {
        let mut m = Mat::filled_with(4, 8, |r, c| (r * 8 + c) as f32 + 1.0);
        let ptr = m.data.as_ptr();
        m.reset(2, 5);
        assert_eq!((m.rows, m.cols), (2, 5));
        assert!(m.data.iter().all(|&x| x == 0.0));
        assert_eq!(m.data.as_ptr(), ptr, "shrinking reset must not realloc");
        m.reset(4, 8);
        assert_eq!(m.data.as_ptr(), ptr, "growing back within capacity must not realloc");
        assert!(m.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn copy_from_matches_clone() {
        let src = Mat::filled_with(3, 4, |r, c| (r + c) as f32);
        let mut dst = Mat::zeros(5, 5);
        dst.copy_from(&src);
        assert_eq!(dst, src);
    }

    #[test]
    fn view_windows_columns_without_copying() {
        let m = Mat::filled_with(3, 6, |r, c| (r * 10 + c) as f32);
        let v = MatView::cols(&m, 2, 3);
        assert_eq!(v.rows, 3);
        assert_eq!(v.cols, 3);
        assert_eq!(v.row(1), &[12.0, 13.0, 14.0]);
        assert_eq!(v.to_mat().at(2, 0), 22.0);
        let first = MatView::full(&m).first_cols(2);
        assert_eq!(first.row(2), &[20.0, 21.0]);
    }

    #[test]
    #[should_panic(expected = "column window out of range")]
    fn view_cols_bounds_checked() {
        let m = Mat::zeros(2, 4);
        MatView::cols(&m, 3, 2);
    }

    fn ramp(rows: usize, cols: usize) -> Mat {
        Mat::filled_with(rows, cols, |r, c| {
            ((r * 37 + c * 23) % 19) as f32 * 0.37 - 3.1
        })
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what} diverged at {i}: {x} vs {y}");
        }
    }

    /// Every fused row primitive must be invariant to how the row set is
    /// partitioned into whole-row chunks — the property the generalized
    /// GEMM epilogue's bitwise thread-invariance stands on.  Odd widths
    /// exercise the lane tails.
    #[test]
    fn row_primitives_are_chunking_invariant() {
        for cols in [1usize, 7, 8, 13, 16, 21] {
            let bias: Vec<f32> = (0..cols).map(|i| i as f32 * 0.11 - 0.4).collect();
            let scale: Vec<f32> = (0..cols).map(|i| 1.0 + i as f32 * 0.02).collect();
            let whole = ramp(6, cols);
            let apply_whole = |f: &dyn Fn(&mut [f32])| {
                let mut m = whole.clone();
                f(&mut m.data);
                m
            };
            let apply_chunked = |f: &dyn Fn(&mut [f32])| {
                let mut m = whole.clone();
                let mut rest = &mut m.data[..];
                for nr in [1usize, 3, 2] {
                    let (head, tail) = rest.split_at_mut(nr * cols);
                    f(head);
                    rest = tail;
                }
                m
            };
            let cases: Vec<(&str, Box<dyn Fn(&mut [f32])>)> = vec![
                ("bias", Box::new(|d: &mut [f32]| bias_rows(d, cols, &bias))),
                ("gelu", Box::new(|d: &mut [f32]| gelu_rows(d, cols))),
                (
                    "bias_gelu",
                    Box::new(|d: &mut [f32]| bias_gelu_rows(d, cols, &bias)),
                ),
                (
                    "layer_norm",
                    Box::new(|d: &mut [f32]| {
                        layer_norm_slice_rows(d, cols, &scale, &bias, 1e-5)
                    }),
                ),
                (
                    "bias_gelu_ln",
                    Box::new(|d: &mut [f32]| {
                        bias_gelu_ln_rows(d, cols, &bias, &scale, &bias, 1e-5)
                    }),
                ),
            ];
            for (name, f) in &cases {
                let a = apply_whole(f.as_ref());
                let b = apply_chunked(f.as_ref());
                assert_bits_eq(&a.data, &b.data, name);
            }
        }
    }

    /// The composed primitives must equal the standalone pass sequences
    /// they fuse, bit for bit — `bias_gelu` vs `add_row_vec` +
    /// `gelu_inplace`, `bias_gelu_ln` vs the three-pass mlm head, and
    /// `layer_norm_rows_into` vs `copy_from` + `layer_norm_rows`.
    #[test]
    fn composed_primitives_match_standalone_passes_bitwise() {
        for cols in [5usize, 8, 12, 17] {
            let bias: Vec<f32> = (0..cols).map(|i| i as f32 * 0.13 - 0.5).collect();
            let scale: Vec<f32> = (0..cols).map(|i| 1.0 - i as f32 * 0.03).collect();
            let lnb: Vec<f32> = (0..cols).map(|i| i as f32 * 0.07).collect();
            let src = ramp(4, cols);

            let mut fused = src.clone();
            bias_gelu_rows(&mut fused.data, cols, &bias);
            let mut two = src.clone();
            two.add_row_vec(&bias);
            gelu_inplace(&mut two);
            assert_bits_eq(&fused.data, &two.data, "bias_gelu");

            let mut fused = src.clone();
            bias_gelu_ln_rows(&mut fused.data, cols, &bias, &scale, &lnb, 1e-5);
            let mut three = src.clone();
            three.add_row_vec(&bias);
            gelu_inplace(&mut three);
            layer_norm_rows(&mut three, &scale, &lnb, 1e-5);
            assert_bits_eq(&fused.data, &three.data, "bias_gelu_ln");

            let mut into = Mat::zeros(4, cols);
            layer_norm_rows_into(&mut into.data, &src.data, cols, &scale, &lnb, 1e-5);
            let mut copied = Mat::zeros(1, 1);
            copied.copy_from(&src);
            layer_norm_rows(&mut copied, &scale, &lnb, 1e-5);
            assert_bits_eq(&into.data, &copied.data, "ln_into");
        }
    }

    /// The residual epilogue must equal the pass sequence it deletes:
    /// `t = c + bias` (rounded once), `x += t`, `h = LN(x)` — and the
    /// in-place final flavour must match residual-then-LN-in-place.
    #[test]
    fn residual_primitives_match_three_pass_form_bitwise() {
        for cols in [6usize, 8, 11, 24] {
            let bias: Vec<f32> = (0..cols).map(|i| i as f32 * 0.09 - 0.3).collect();
            let scale: Vec<f32> = (0..cols).map(|i| 1.0 + i as f32 * 0.01).collect();
            let lnb: Vec<f32> = (0..cols).map(|i| 0.2 - i as f32 * 0.02).collect();
            let c = ramp(5, cols);
            let x0 = Mat::filled_with(5, cols, |r, cc| {
                ((r * 13 + cc * 29) % 11) as f32 * 0.21 - 1.0
            });

            // reference: the standalone three-pass form
            let mut t = c.clone();
            t.add_row_vec(&bias);
            let mut x_ref = x0.clone();
            x_ref.add_assign(&t);
            let mut h_ref = Mat::zeros(1, 1);
            h_ref.copy_from(&x_ref);
            layer_norm_rows(&mut h_ref, &scale, &lnb, 1e-5);

            let mut x = x0.clone();
            let mut h = Mat::zeros(5, cols);
            bias_residual_ln_rows(
                &c.data, &mut x.data, &mut h.data, cols, &bias, &scale, &lnb,
                1e-5,
            );
            assert_bits_eq(&x.data, &x_ref.data, "residual x");
            assert_bits_eq(&h.data, &h_ref.data, "residual h");

            let mut xi = x0.clone();
            bias_residual_ln_inplace_rows(
                &c.data, &mut xi.data, cols, &bias, &scale, &lnb, 1e-5,
            );
            assert_bits_eq(&xi.data, &h_ref.data, "residual inplace ln");

            let mut xr = x0.clone();
            bias_residual_rows(&c.data, &mut xr.data, cols, &bias);
            assert_bits_eq(&xr.data, &x_ref.data, "residual only");
        }
    }
}
