//! One-sided Jacobi SVD (singular values only + optional vectors).
//!
//! Powers the Fig 1 reproduction: the paper computes the cumulative
//! normalized singular-value spectrum of attention matrices P ∈ R^{n×n}.
//! One-sided Jacobi orthogonalizes the columns of A by Givens rotations;
//! the column norms converge to the singular values.  O(n³) per sweep but
//! robust and dependency-free; n ≤ 512 here, which is what the paper used.

use super::Mat;

/// Result of an SVD: singular values in descending order.
#[derive(Debug, Clone)]
pub struct Svd {
    pub singular_values: Vec<f32>,
    pub sweeps: usize,
}

/// Compute singular values of `a` (m×n, m ≥ n is not required — the matrix
/// is transposed internally when n > m for speed).
pub fn singular_values(a: &Mat) -> Svd {
    let work = if a.cols > a.rows { a.transpose() } else { a.clone() };
    jacobi(work)
}

fn jacobi(mut a: Mat) -> Svd {
    let n = a.cols;
    let max_sweeps = 30;
    let eps = 1e-9f64;
    let mut sweeps = 0;
    // Work in f64 accumulators for the rotations' dot products: the
    // convergence test is on relative off-diagonal mass.
    for sweep in 0..max_sweeps {
        sweeps = sweep + 1;
        let mut off = 0.0f64;
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                // alpha = a_p . a_p ; beta = a_q . a_q ; gamma = a_p . a_q
                let (mut alpha, mut beta, mut gamma) = (0.0f64, 0.0f64, 0.0f64);
                for r in 0..a.rows {
                    let x = f64::from(a.at(r, p));
                    let y = f64::from(a.at(r, q));
                    alpha += x * x;
                    beta += y * y;
                    gamma += x * y;
                }
                if alpha == 0.0 || beta == 0.0 {
                    continue;
                }
                let limit = eps * (alpha * beta).sqrt();
                if gamma.abs() <= limit {
                    continue;
                }
                off += gamma.abs() / (alpha * beta).sqrt();
                // Givens rotation zeroing the (p,q) inner product.
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for r in 0..a.rows {
                    let x = f64::from(a.at(r, p));
                    let y = f64::from(a.at(r, q));
                    *a.at_mut(r, p) = (c * x - s * y) as f32;
                    *a.at_mut(r, q) = (s * x + c * y) as f32;
                }
            }
        }
        if off < 1e-7 {
            break;
        }
    }
    let mut sv: Vec<f32> = (0..n)
        .map(|j| {
            (0..a.rows)
                .map(|r| {
                    let x = f64::from(a.at(r, j));
                    x * x
                })
                .sum::<f64>()
                .sqrt() as f32
        })
        .collect();
    sv.sort_by(|x, y| y.partial_cmp(x).unwrap());
    Svd { singular_values: sv, sweeps }
}

/// Normalized cumulative spectrum: out[i] = sum(sv[..=i]) / sum(sv).
/// This is exactly the Y-axis of the paper's Figure 1 (left).
pub fn cumulative_spectrum(sv: &[f32]) -> Vec<f32> {
    let total: f32 = sv.iter().sum();
    if total == 0.0 {
        return vec![0.0; sv.len()];
    }
    let mut acc = 0.0;
    sv.iter()
        .map(|s| {
            acc += s;
            acc / total
        })
        .collect()
}

/// Effective rank: smallest r with cumulative spectrum ≥ threshold.
pub fn effective_rank(sv: &[f32], threshold: f32) -> usize {
    let cum = cumulative_spectrum(sv);
    cum.iter().position(|&c| c >= threshold).map_or(sv.len(), |p| p + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::util::rng::Pcg32;

    #[test]
    fn diagonal_matrix_svs_are_abs_diagonal() {
        let mut m = Mat::zeros(4, 4);
        for (i, v) in [3.0f32, -7.0, 1.0, 0.5].iter().enumerate() {
            *m.at_mut(i, i) = *v;
        }
        let svd = singular_values(&m);
        let want = [7.0, 3.0, 1.0, 0.5];
        for (got, want) in svd.singular_values.iter().zip(want) {
            assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        }
    }

    #[test]
    fn orthogonal_matrix_svs_are_ones() {
        // rotation matrix
        let th = 0.7f32;
        let m = Mat::from_vec(2, 2, vec![th.cos(), -th.sin(), th.sin(), th.cos()]);
        let svd = singular_values(&m);
        for s in svd.singular_values {
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn rank_one_matrix_has_single_nonzero_sv() {
        let u = Mat::from_vec(5, 1, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let v = Mat::from_vec(1, 4, vec![1.0, 0.0, -1.0, 2.0]);
        let m = matmul(&u, &v);
        let svd = singular_values(&m);
        assert!(svd.singular_values[0] > 1.0);
        for s in &svd.singular_values[1..] {
            assert!(s.abs() < 1e-3, "{s}");
        }
        assert_eq!(effective_rank(&svd.singular_values, 0.99), 1);
    }

    #[test]
    fn frobenius_norm_is_preserved() {
        // sum sv^2 == ||A||_F^2
        let mut rng = Pcg32::seeded(11);
        let mut m = Mat::zeros(20, 12);
        rng.fill_normal(&mut m.data, 1.0);
        let svd = singular_values(&m);
        let sum_sq: f32 = svd.singular_values.iter().map(|s| s * s).sum();
        let fro2 = m.fro_norm().powi(2);
        assert!((sum_sq - fro2).abs() / fro2 < 1e-3);
    }

    #[test]
    fn wide_and_tall_agree() {
        let mut rng = Pcg32::seeded(12);
        let mut m = Mat::zeros(8, 15);
        rng.fill_normal(&mut m.data, 1.0);
        let a = singular_values(&m);
        let b = singular_values(&m.transpose());
        for (x, y) in a.singular_values.iter().zip(&b.singular_values) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn cumulative_spectrum_monotone_to_one() {
        let cum = cumulative_spectrum(&[4.0, 3.0, 2.0, 1.0]);
        assert!((cum.last().unwrap() - 1.0).abs() < 1e-6);
        for w in cum.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!((cum[0] - 0.4).abs() < 1e-6);
    }

    #[test]
    fn low_rank_plus_noise_spectrum_is_skewed() {
        // Construct rank-3 + tiny noise; effective rank at 0.9 must be small.
        let mut rng = Pcg32::seeded(13);
        let mut u = Mat::zeros(32, 3);
        let mut v = Mat::zeros(3, 32);
        rng.fill_normal(&mut u.data, 1.0);
        rng.fill_normal(&mut v.data, 1.0);
        let mut m = matmul(&u, &v);
        for x in &mut m.data {
            *x += rng.normal() * 1e-3;
        }
        let svd = singular_values(&m);
        assert!(effective_rank(&svd.singular_values, 0.9) <= 3);
    }
}
