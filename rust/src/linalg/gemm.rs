//! Blocked, optionally multi-threaded f32 GEMM over [`Mat`] and strided
//! [`MatView`]s.
//!
//! `matmul` computes `C = A·B`, `matmul_nt` computes `C = A·Bᵀ` (the layout
//! attention wants for Q·Kᵀ without materialising a transpose).  Both use
//! cache blocking plus an 8-wide unrolled inner kernel, and above
//! [`PAR_FLOP_THRESHOLD`] they row-partition the output into tasks on the
//! process-wide persistent [`pool`](super::pool) — no per-call thread
//! spawns, and concurrent callers (e.g. several serving buckets) share the
//! one global compute budget instead of each planning against the whole
//! machine.
//!
//! # Determinism
//!
//! Every output row is produced by exactly one task running the same
//! serial per-row kernel in the same accumulation order (ascending `k`),
//! so results are **bitwise identical** for any worker cap or pool size —
//! the `threaded_matches_serial_bitwise` test pins this down.  This is
//! what lets `encode_batch` parallelise freely while still matching
//! per-example `encode` bit-for-bit.
//!
//! # NaN/Inf propagation
//!
//! The old serial kernel skipped `A[i][k] == 0.0` rows of B as a sparsity
//! fast path, which silently dropped NaN/Inf coming from B
//! (`0.0 * NaN = NaN` must surface).  The branch is gone; the
//! `nan_propagates_through_zero_entries` test keeps it gone.

use super::{pool, Mat, MatView};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Once;

const BLOCK_M: usize = 64;
const BLOCK_N: usize = 64;
const BLOCK_K: usize = 256;

/// Below this many FLOPs (2·m·k·n) a GEMM stays serial: thread spawn and
/// join overhead (~tens of µs) would dominate the kernel.
pub const PAR_FLOP_THRESHOLD: usize = 1 << 22;

/// Process-wide worker cap (0 = not yet resolved).
static THREAD_CAP: AtomicUsize = AtomicUsize::new(0);

/// Warn about a malformed `LINFORMER_THREADS` at most once per process.
static ENV_WARNING: Once = Once::new();

/// Cap the number of GEMM worker threads (also settable via the
/// `LINFORMER_THREADS` env var; defaults to `available_parallelism`).
///
/// This is also the size of the process-wide [`pool`] — call it (or set
/// the env var) *before* any parallel work runs; once the pool exists its
/// worker count is fixed, and later changes only affect how many tasks a
/// single GEMM is split into.
pub fn set_max_threads(n: usize) {
    THREAD_CAP.store(n.max(1), Ordering::Relaxed);
}

/// Parse a `LINFORMER_THREADS`-style value.  Returns the cap plus whether
/// the raw value was valid; invalid values (zero, negative, non-numeric)
/// fall back to `default` rather than silently degenerating the thread
/// plan to a useless cap.
fn parse_thread_env(raw: &str, default: usize) -> (usize, bool) {
    match raw.trim().parse::<usize>() {
        Ok(t) if t > 0 => (t, true),
        _ => (default, false),
    }
}

/// Resolved worker cap for this process — the global compute budget.
pub fn max_threads() -> usize {
    let t = THREAD_CAP.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let default =
        std::thread::available_parallelism().map_or(1, |n| n.get());
    let t = match std::env::var("LINFORMER_THREADS") {
        Ok(raw) => {
            let (t, valid) = parse_thread_env(&raw, default);
            if !valid {
                ENV_WARNING.call_once(|| {
                    eprintln!(
                        "[linformer] warning: LINFORMER_THREADS={raw:?} is \
                         not a positive integer; falling back to \
                         available_parallelism ({default})"
                    );
                });
            }
            t
        }
        Err(_) => default,
    };
    THREAD_CAP.store(t, Ordering::Relaxed);
    t
}

/// Worker count for an (m × k) · (k × n) product under a caller cap:
/// 1 below [`PAR_FLOP_THRESHOLD`], else `cap` clamped to the row count.
pub fn plan_threads(m: usize, k: usize, n: usize, cap: usize) -> usize {
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(k)
        .saturating_mul(n);
    if flops < PAR_FLOP_THRESHOLD {
        1
    } else {
        cap.min(m).max(1)
    }
}

/// C = A (m×k) · B (k×n), auto-threaded.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(0, 0);
    matmul_into(a, b, &mut c);
    c
}

/// C = A·B into a reusable output buffer (resized in place, no
/// reallocation once its capacity suffices).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let t = plan_threads(a.rows, a.cols, b.cols, max_threads());
    matmul_view(MatView::full(a), MatView::full(b), c, t);
}

/// C = A (m×k) · Bᵀ where B is (n×k): dot products of rows, auto-threaded.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(0, 0);
    matmul_nt_into(a, b, &mut c);
    c
}

/// C = A·Bᵀ into a reusable output buffer.
pub fn matmul_nt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let t = plan_threads(a.rows, a.cols, b.rows, max_threads());
    matmul_nt_view(MatView::full(a), MatView::full(b), c, t);
}

/// C = A·B over strided views with an explicit worker cap.  `c` is
/// resized (allocation-free after warmup) and fully overwritten.  Above
/// one worker the rows are partitioned into tasks on the global
/// [`pool`]; partitioning depends only on `threads`, so output is
/// bitwise identical for any pool size.
pub fn matmul_view(a: MatView<'_>, b: MatView<'_>, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols, b.rows, "matmul inner dims: {} vs {}", a.cols, b.rows);
    c.reset(a.rows, b.cols);
    let (m, n) = (a.rows, b.cols);
    if m == 0 || n == 0 || a.cols == 0 {
        return;
    }
    run_row_chunks(&mut c.data, m, threads, n, move |chunk, row0| {
        mm_rows(a, b, chunk, row0)
    });
}

/// C = A·Bᵀ over strided views with an explicit worker cap.
pub fn matmul_nt_view(a: MatView<'_>, b: MatView<'_>, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dims: {} vs {}", a.cols, b.cols);
    c.reset(a.rows, b.rows);
    let (m, n) = (a.rows, b.rows);
    if m == 0 || n == 0 {
        return;
    }
    run_row_chunks(&mut c.data, m, threads, n, move |chunk, row0| {
        mmnt_rows(a, b, chunk, row0)
    });
}

/// `out[:, col0..col0+b.cols] = A·B` — writes the product into a column
/// block of a wider row-major matrix (the per-head context slot), with no
/// intermediate buffer.  Rows outside the block are untouched.
pub fn matmul_view_cols(
    a: MatView<'_>,
    b: MatView<'_>,
    out: &mut Mat,
    col0: usize,
    threads: usize,
) {
    assert_eq!(a.cols, b.rows, "matmul inner dims: {} vs {}", a.cols, b.rows);
    assert_eq!(a.rows, out.rows, "matmul_view_cols: row mismatch");
    assert!(col0 + b.cols <= out.cols, "matmul_view_cols: column overflow");
    let (m, stride) = (a.rows, out.cols);
    if m == 0 || b.cols == 0 {
        return;
    }
    run_row_chunks(&mut out.data, m, threads, stride, move |chunk, row0| {
        mm_cols_rows(a, b, chunk, row0, col0, stride)
    });
}

/// Split `data` (m rows of width `stride`) into up to `threads`
/// contiguous row blocks and run `kernel(chunk, row0)` over each as
/// tasks on the global [`pool`] — the one fork-join shape every GEMM
/// variant shares.  `threads == 1` runs inline on the caller (the
/// serial fast path).  Chunking depends only on `threads`, and each
/// chunk is produced by the same serial kernel either way, so outputs
/// are bitwise identical for any pool size.
fn run_row_chunks<'env, K>(
    data: &'env mut [f32],
    m: usize,
    threads: usize,
    stride: usize,
    kernel: K,
) where
    K: Fn(&mut [f32], usize) + Send + Copy + 'env,
{
    let t = threads.clamp(1, m);
    if t == 1 {
        kernel(data, 0);
        return;
    }
    let rows_per = (m + t - 1) / t;
    let tasks: Vec<pool::Task<'env>> = data
        .chunks_mut(rows_per * stride)
        .enumerate()
        .map(|(w, chunk)| {
            Box::new(move || kernel(chunk, w * rows_per)) as pool::Task<'env>
        })
        .collect();
    pool::global().run(tasks);
}

/// Serial blocked kernel over output rows `row0..row0 + c.len()/n` of A·B.
/// `c` is the contiguous, zeroed output block for those rows.
fn mm_rows(a: MatView<'_>, b: MatView<'_>, c: &mut [f32], row0: usize) {
    let k = a.cols;
    let n = b.cols;
    let rows = c.len() / n;
    for i0 in (0..rows).step_by(BLOCK_M) {
        let i1 = (i0 + BLOCK_M).min(rows);
        for k0 in (0..k).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(k);
            for j0 in (0..n).step_by(BLOCK_N) {
                let j1 = (j0 + BLOCK_N).min(n);
                for i in i0..i1 {
                    let arow = a.row(row0 + i);
                    let crow = &mut c[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        // no zero-skip: 0.0 * NaN must stay NaN
                        axpy(arow[kk], &b.row(kk)[j0..j1], &mut crow[j0..j1]);
                    }
                }
            }
        }
    }
}

/// Serial kernel over output rows of A·Bᵀ.
fn mmnt_rows(a: MatView<'_>, b: MatView<'_>, c: &mut [f32], row0: usize) {
    let n = b.rows;
    let rows = c.len() / n;
    for i in 0..rows {
        let arow = a.row(row0 + i);
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = dot(arow, b.row(j));
        }
    }
}

/// Serial kernel writing A·B into columns `[col0, col0+b.cols)` of a
/// stride-`stride` output block.
fn mm_cols_rows(
    a: MatView<'_>,
    b: MatView<'_>,
    chunk: &mut [f32],
    row0: usize,
    col0: usize,
    stride: usize,
) {
    let rows = chunk.len() / stride;
    let w = b.cols;
    for i in 0..rows {
        let arow = a.row(row0 + i);
        let base = i * stride + col0;
        let crow = &mut chunk[base..base + w];
        crow.fill(0.0);
        for (kk, &av) in arow.iter().enumerate() {
            axpy(av, b.row(kk), crow);
        }
    }
}

/// y += alpha * x, 8-way unrolled.
#[inline]
pub(crate) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let chunks = n / 8;
    for c in 0..chunks {
        let o = c * 8;
        // manual unroll — the autovectorizer turns this into fma lanes
        y[o] += alpha * x[o];
        y[o + 1] += alpha * x[o + 1];
        y[o + 2] += alpha * x[o + 2];
        y[o + 3] += alpha * x[o + 3];
        y[o + 4] += alpha * x[o + 4];
        y[o + 5] += alpha * x[o + 5];
        y[o + 6] += alpha * x[o + 6];
        y[o + 7] += alpha * x[o + 7];
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// Unrolled dot product with 4 accumulators (breaks the dependency chain).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    let chunks = n / 4;
    for c in 0..chunks {
        let o = c * 4;
        s0 += x[o] * y[o];
        s1 += x[o + 1] * y[o + 1];
        s2 += x[o + 2] * y[o + 2];
        s3 += x[o + 3] * y[o + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..n {
        tail += x[i] * y[i];
    }
    s0 + s1 + s2 + s3 + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += f64::from(a.at(i, k)) * f64::from(b.at(k, j));
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    fn rand_mat(rng: &mut Pcg32, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(&mut m.data, 1.0);
        m
    }

    #[test]
    fn matmul_matches_naive_on_odd_shapes() {
        let mut rng = Pcg32::seeded(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (65, 130, 70)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "({m},{k},{n}): {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn threaded_matches_serial_bitwise() {
        let mut rng = Pcg32::seeded(9);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (65, 130, 70), (64, 64, 64)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let (av, bv) = (MatView::full(&a), MatView::full(&b));
            let mut serial = Mat::zeros(0, 0);
            matmul_view(av, bv, &mut serial, 1);
            for threads in [2, 3, 4, 7] {
                let mut par = Mat::zeros(0, 0);
                matmul_view(av, bv, &mut par, threads);
                assert_eq!(
                    serial.data, par.data,
                    "({m},{k},{n}) with {threads} threads is not bitwise equal"
                );
            }
            // same property for the transposed kernel
            let bt = rand_mat(&mut rng, n, k);
            let btv = MatView::full(&bt);
            let mut serial = Mat::zeros(0, 0);
            matmul_nt_view(av, btv, &mut serial, 1);
            for threads in [2, 5] {
                let mut par = Mat::zeros(0, 0);
                matmul_nt_view(av, btv, &mut par, threads);
                assert_eq!(serial.data, par.data);
            }
        }
    }

    #[test]
    fn nan_propagates_through_zero_entries() {
        // A has a 0.0 exactly where B carries NaN / Inf: the product must
        // be NaN (0·NaN = NaN, 0·Inf = NaN) — the old zero-skip ate it.
        let a = Mat::from_vec(1, 2, vec![0.0, 1.0]);
        let b = Mat::from_vec(2, 2, vec![f32::NAN, f32::INFINITY, 3.0, 4.0]);
        let c = matmul(&a, &b);
        assert!(c.at(0, 0).is_nan(), "NaN dropped: {}", c.at(0, 0));
        assert!(c.at(0, 1).is_nan(), "Inf·0 dropped: {}", c.at(0, 1));
    }

    #[test]
    fn matmul_into_reuses_buffer() {
        let mut rng = Pcg32::seeded(4);
        let a = rand_mat(&mut rng, 9, 11);
        let b = rand_mat(&mut rng, 11, 5);
        let mut c = Mat::zeros(0, 0);
        matmul_into(&a, &b, &mut c);
        let want = c.clone();
        let ptr = c.data.as_ptr();
        let cap = c.data.capacity();
        // stale garbage in the buffer must not leak into the next product
        c.data.iter_mut().for_each(|x| *x = f32::NAN);
        matmul_into(&a, &b, &mut c);
        assert_eq!(c.data, want.data);
        assert_eq!(c.data.as_ptr(), ptr, "buffer was reallocated");
        assert_eq!(c.data.capacity(), cap);
    }

    #[test]
    fn strided_views_match_materialized_slices() {
        let mut rng = Pcg32::seeded(5);
        let packed = rand_mat(&mut rng, 13, 12); // 3 heads × 4 cols
        let other = rand_mat(&mut rng, 13, 4);
        for head in 0..3 {
            let view = MatView::cols(&packed, head * 4, 4);
            let copy = view.to_mat();
            assert_eq!(copy.rows, 13);
            assert_eq!(copy.cols, 4);
            // view GEMM == owned GEMM, bitwise
            let mut from_view = Mat::zeros(0, 0);
            matmul_nt_view(view, MatView::full(&other), &mut from_view, 1);
            let want = matmul_nt(&copy, &other);
            assert_eq!(from_view.data, want.data);
        }
    }

    #[test]
    fn view_cols_writes_only_its_block() {
        let mut rng = Pcg32::seeded(6);
        let logits = rand_mat(&mut rng, 7, 5);
        let v = rand_mat(&mut rng, 5, 3);
        let want = matmul(&logits, &v);
        let mut ctx = Mat::filled_with(7, 10, |_, _| 99.0);
        for threads in [1, 3] {
            matmul_view_cols(
                MatView::full(&logits),
                MatView::full(&v),
                &mut ctx,
                4,
                threads,
            );
            for r in 0..7 {
                for c in 0..3 {
                    assert_eq!(ctx.at(r, 4 + c), want.at(r, c));
                }
                assert_eq!(ctx.at(r, 0), 99.0, "wrote outside the block");
                assert_eq!(ctx.at(r, 9), 99.0, "wrote outside the block");
            }
        }
    }

    #[test]
    fn plan_threads_keeps_small_gemms_serial() {
        assert_eq!(plan_threads(32, 16, 16, 8), 1);
        assert!(plan_threads(512, 512, 512, 8) > 1);
        // never more workers than rows
        assert_eq!(plan_threads(2, 4096, 4096, 8), 2);
    }

    #[test]
    fn matmul_nt_equals_matmul_with_transpose() {
        let mut rng = Pcg32::seeded(1);
        let a = rand_mat(&mut rng, 13, 21);
        let b = rand_mat(&mut rng, 17, 21);
        let got = matmul_nt(&a, &b);
        let want = matmul(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg32::seeded(2);
        let a = rand_mat(&mut rng, 8, 8);
        assert!(matmul(&a, &Mat::eye(8)).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&Mat::eye(8), &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn dot_matches_reference() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (0..37).map(|i| (37 - i) as f32).collect();
        let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - want).abs() < 1e-2);
    }

    #[test]
    #[should_panic(expected = "matmul inner dims")]
    fn shape_mismatch_panics() {
        matmul(&Mat::zeros(2, 3), &Mat::zeros(4, 2));
    }

    #[test]
    fn thread_env_zero_falls_back_to_default() {
        let (t, valid) = parse_thread_env("0", 8);
        assert_eq!(t, 8);
        assert!(!valid, "0 must be rejected, not become a degenerate plan");
    }

    #[test]
    fn thread_env_garbage_falls_back_to_default() {
        for raw in ["abc", "", "-3", "4.5", "1e3"] {
            let (t, valid) = parse_thread_env(raw, 6);
            assert_eq!(t, 6, "raw {raw:?}");
            assert!(!valid, "raw {raw:?} must be rejected");
        }
    }

    #[test]
    fn thread_env_valid_values_pass_through() {
        assert_eq!(parse_thread_env("4", 8), (4, true));
        assert_eq!(parse_thread_env(" 16 ", 8), (16, true));
    }

    #[test]
    fn pool_gemm_matches_serial_for_any_chunking() {
        // same property as threaded_matches_serial_bitwise, phrased
        // against the pool explicitly: however the rows are chunked into
        // pool tasks, output is bitwise identical to the serial kernel
        let mut rng = Pcg32::seeded(21);
        let a = rand_mat(&mut rng, 37, 53);
        let b = rand_mat(&mut rng, 53, 29);
        let (av, bv) = (MatView::full(&a), MatView::full(&b));
        let mut serial = Mat::zeros(0, 0);
        matmul_view(av, bv, &mut serial, 1);
        for chunks in [2, 8, 37, 64] {
            let mut pooled = Mat::zeros(0, 0);
            matmul_view(av, bv, &mut pooled, chunks);
            assert_eq!(serial.data, pooled.data, "{chunks} chunks diverged");
        }
    }
}
