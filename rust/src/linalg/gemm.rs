//! Blocked single-threaded f32 GEMM.
//!
//! `matmul` computes `C = A·B`, `matmul_nt` computes `C = A·Bᵀ` (the layout
//! attention wants for Q·Kᵀ without materialising a transpose).  Both use
//! cache blocking plus an 8-wide unrolled inner kernel; good enough that the
//! Rust reference model is compute- rather than overhead-bound.

use super::Mat;

const BLOCK_M: usize = 64;
const BLOCK_N: usize = 64;
const BLOCK_K: usize = 256;

/// C = A (m×k) · B (k×n).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner dims: {} vs {}", a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    for i0 in (0..m).step_by(BLOCK_M) {
        let i1 = (i0 + BLOCK_M).min(m);
        for k0 in (0..k).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(k);
            for j0 in (0..n).step_by(BLOCK_N) {
                let j1 = (j0 + BLOCK_N).min(n);
                for i in i0..i1 {
                    let arow = &a.data[i * k..(i + 1) * k];
                    let crow = &mut c.data[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n..(kk + 1) * n];
                        axpy(av, &brow[j0..j1], &mut crow[j0..j1]);
                    }
                }
            }
        }
    }
    c
}

/// C = A (m×k) · Bᵀ where B is (n×k): dot products of rows.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dims: {} vs {}", a.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] = dot(arow, &b.data[j * k..(j + 1) * k]);
        }
    }
    c
}

/// y += alpha * x, 8-way unrolled.
#[inline]
fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let chunks = n / 8;
    for c in 0..chunks {
        let o = c * 8;
        // manual unroll — the autovectorizer turns this into fma lanes
        y[o] += alpha * x[o];
        y[o + 1] += alpha * x[o + 1];
        y[o + 2] += alpha * x[o + 2];
        y[o + 3] += alpha * x[o + 3];
        y[o + 4] += alpha * x[o + 4];
        y[o + 5] += alpha * x[o + 5];
        y[o + 6] += alpha * x[o + 6];
        y[o + 7] += alpha * x[o + 7];
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// Unrolled dot product with 4 accumulators (breaks the dependency chain).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    let chunks = n / 4;
    for c in 0..chunks {
        let o = c * 4;
        s0 += x[o] * y[o];
        s1 += x[o + 1] * y[o + 1];
        s2 += x[o + 2] * y[o + 2];
        s3 += x[o + 3] * y[o + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..n {
        tail += x[i] * y[i];
    }
    s0 + s1 + s2 + s3 + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += f64::from(a.at(i, k)) * f64::from(b.at(k, j));
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    fn rand_mat(rng: &mut Pcg32, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(&mut m.data, 1.0);
        m
    }

    #[test]
    fn matmul_matches_naive_on_odd_shapes() {
        let mut rng = Pcg32::seeded(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (65, 130, 70)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "({m},{k},{n}): {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn matmul_nt_equals_matmul_with_transpose() {
        let mut rng = Pcg32::seeded(1);
        let a = rand_mat(&mut rng, 13, 21);
        let b = rand_mat(&mut rng, 17, 21);
        let got = matmul_nt(&a, &b);
        let want = matmul(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg32::seeded(2);
        let a = rand_mat(&mut rng, 8, 8);
        assert!(matmul(&a, &Mat::eye(8)).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&Mat::eye(8), &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn dot_matches_reference() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (0..37).map(|i| (37 - i) as f32).collect();
        let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - want).abs() < 1e-2);
    }

    #[test]
    #[should_panic(expected = "matmul inner dims")]
    fn shape_mismatch_panics() {
        matmul(&Mat::zeros(2, 3), &Mat::zeros(4, 2));
    }
}
