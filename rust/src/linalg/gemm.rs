//! Blocked, optionally multi-threaded f32 GEMM over [`Mat`] and strided
//! [`MatView`]s.
//!
//! `matmul` computes `C = A·B`, `matmul_nt` computes `C = A·Bᵀ` (the layout
//! attention wants for Q·Kᵀ without materialising a transpose).  Since the
//! SIMD-microkernel rework, every entry point — `matmul_view`,
//! `matmul_nt_view`, `matmul_view_cols`, serial or pool-parallel — funnels
//! into the one explicit [`kernel`] path: B is packed into lane-aligned
//! `NR`-wide panels once per call, then `MR×NR` register tiles of C are
//! computed with portable [`kernel::F32x8`] lanes (see `linalg/kernel.rs`
//! for the design).  The old autovectorizer-dependent scalar kernels are
//! kept as a measured baseline: build with `--features scalar-gemm` (or
//! pass a [`GemmScratch::scalar`]) to route through them instead.
//!
//! Above [`PAR_FLOP_THRESHOLD`] the output rows are partitioned into tasks
//! on the process-wide persistent [`pool`](super::pool) — no per-call
//! thread spawns, and concurrent callers (e.g. several serving buckets)
//! share the one global compute budget instead of each planning against
//! the whole machine.
//!
//! # Determinism
//!
//! Every output element is one accumulator updated in ascending `k` order
//! by the same unfused multiply-add sequence, whichever tile shape, chunk
//! or worker computed it — so results are **bitwise identical** for any
//! worker cap or pool size (pinned by `threaded_matches_serial_bitwise` /
//! `pool_gemm_matches_serial_for_any_chunking`), and the `A·B` paths are
//! additionally bitwise identical to the scalar fallback (pinned by
//! `simd_matches_scalar_bitwise`).  This is what lets `encode_batch`
//! parallelise freely while still matching per-example `encode`
//! bit-for-bit.
//!
//! # NaN/Inf propagation
//!
//! The pre-rework serial kernel skipped `A[i][k] == 0.0` rows of B as a
//! sparsity fast path, which silently dropped NaN/Inf coming from B
//! (`0.0 * NaN = NaN` must surface).  Neither kernel has such a branch;
//! the `nan_propagates_through_zero_entries` test keeps it that way.
//!
//! # Packed weights and the int8 path
//!
//! Weight matrices are immutable between registry reloads, so their
//! packed panels can be built **once per `Params` generation** instead
//! of once per GEMM call: [`PackedPanels`] owns one pre-packed B-operand
//! image ([`Dtype::F32`], bitwise identical to packing per call) or a
//! pre-quantized i8 image plus per-output-channel scales
//! ([`Dtype::Int8`]), and [`matmul_packed_view_in`] consumes it with
//! zero per-call packing or quantization of the weight side.  The int8
//! flavor quantizes the activation side per tensor into the scratch,
//! accumulates exactly in i32 and dequantizes in the kernel epilogue —
//! bitwise deterministic across thread counts because integer
//! accumulation is exact.  Packed entry points always run the
//! microkernel (panels are its format); a scalar-pinned scratch should
//! use the unpacked entry points.
//!
//! For tall GEMMs (`m ≥ kernel::A_PACK_MIN_M`) the f32 paths also pack
//! A into `MR`-row panels — same values in the same order, so all the
//! bitwise guarantees above are unaffected (row chunks round up to `MR`
//! so pack panels coincide with chunk-local tiles).
//!
//! # Length contracts
//!
//! [`dot`] and [`axpy`] require equal-length inputs, asserted
//! unconditionally.  They used to compute over the shorter prefix of
//! mismatched slices, which turned upstream shape bugs into silently
//! wrong numbers instead of a panic.

use super::kernel::{self, F32x8, PackBuf, PackBufI8, LANES};
use super::{pool, Mat, MatView};
use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Once;

const BLOCK_M: usize = 64;
const BLOCK_N: usize = 64;
const BLOCK_K: usize = 256;

/// Below this many FLOPs (2·m·k·n) a GEMM stays serial: thread spawn and
/// join overhead (~tens of µs) would dominate the kernel.  Retuned up
/// from `1 << 22` for the SIMD microkernel — the serial kernel moves
/// 2-4× more FLOPs in the same wall time, so the break-even point where
/// fork/join overhead pays for itself moved up with it.
pub const PAR_FLOP_THRESHOLD: usize = 1 << 23;

/// Process-wide worker cap (0 = not yet resolved).
static THREAD_CAP: AtomicUsize = AtomicUsize::new(0);

/// Warn about a malformed `LINFORMER_THREADS` at most once per process.
static ENV_WARNING: Once = Once::new();

/// Cap the number of GEMM worker threads (also settable via the
/// `LINFORMER_THREADS` env var; defaults to `available_parallelism`).
///
/// This is also the size of the process-wide [`pool`] — call it (or set
/// the env var) *before* any parallel work runs; once the pool exists its
/// worker count is fixed, and later changes only affect how many tasks a
/// single GEMM is split into.
pub fn set_max_threads(n: usize) {
    THREAD_CAP.store(n.max(1), Ordering::Relaxed);
}

/// Parse a `LINFORMER_THREADS`-style value.  Returns the cap plus whether
/// the raw value was valid; invalid values (zero, negative, non-numeric)
/// fall back to `default` rather than silently degenerating the thread
/// plan to a useless cap.
fn parse_thread_env(raw: &str, default: usize) -> (usize, bool) {
    match raw.trim().parse::<usize>() {
        Ok(t) if t > 0 => (t, true),
        _ => (default, false),
    }
}

/// Resolved worker cap for this process — the global compute budget.
pub fn max_threads() -> usize {
    let t = THREAD_CAP.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let default =
        std::thread::available_parallelism().map_or(1, |n| n.get());
    let t = match std::env::var("LINFORMER_THREADS") {
        Ok(raw) => {
            let (t, valid) = parse_thread_env(&raw, default);
            if !valid {
                ENV_WARNING.call_once(|| {
                    eprintln!(
                        "[linformer] warning: LINFORMER_THREADS={raw:?} is \
                         not a positive integer; falling back to \
                         available_parallelism ({default})"
                    );
                });
            }
            t
        }
        Err(_) => default,
    };
    THREAD_CAP.store(t, Ordering::Relaxed);
    t
}

/// Worker count for an (m × k) · (k × n) product under a caller cap:
/// 1 below [`PAR_FLOP_THRESHOLD`], else `cap` clamped to the row count
/// *and* to a plan that leaves every worker at least a quarter threshold
/// of work — fanning a marginal GEMM out to the whole budget just buys
/// per-task overhead and steals workers from concurrent callers.
pub fn plan_threads(m: usize, k: usize, n: usize, cap: usize) -> usize {
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(k)
        .saturating_mul(n);
    if flops < PAR_FLOP_THRESHOLD {
        1
    } else {
        let busy = flops / (PAR_FLOP_THRESHOLD / 4);
        cap.min(m).min(busy.max(1)).max(1)
    }
}

/// Which kernel this build routes the entry points through by default
/// (benches tag their records with it).
pub fn kernel_name() -> &'static str {
    if cfg!(feature = "scalar-gemm") {
        "scalar"
    } else {
        "simd"
    }
}

/// Per-caller GEMM workspace: the B-panel [`PackBuf`], the A-panel
/// buffer for tall GEMMs, the i8 activation-quantization buffer for the
/// packed int8 path, plus the kernel selection.  The encoder keeps one
/// inside its `EncodeScratch` so the warm forward pass packs and
/// quantizes allocation-free; callers without a scratch (tests,
/// benches, svd) go through the entry points that borrow a
/// thread-local one.
#[derive(Debug)]
pub struct GemmScratch {
    pub pack: PackBuf,
    /// A-panel scratch for the `m ≥ kernel::A_PACK_MIN_M` path.
    apack: PackBuf,
    /// Quantized-activation scratch for [`matmul_packed_view_in`] on
    /// int8 panels.
    qa: PackBufI8,
    /// Route through the pre-SIMD scalar kernels (baseline measurements
    /// and bitwise cross-checks).  Defaults to the `scalar-gemm` feature.
    scalar: bool,
    /// Static activation-quantization override for the int8 packed
    /// path: when set, the next int8 GEMM quantizes A at this magnitude
    /// via [`kernel::quantize_activations_with_max`] instead of running
    /// the per-call max-abs scan.  One-shot — consumed (taken) by the
    /// call, so a stale override can never leak into an unrelated GEMM.
    act_max_override: Option<f32>,
    /// Max-abs the last int8 activation *scan* observed (calibration
    /// feed for the encoder's EWMA scale cache).  Untouched when the
    /// scan was skipped via the override.
    observed_act_max: f32,
}

impl Default for GemmScratch {
    /// Same as [`GemmScratch::new`] — in particular the kernel selection
    /// follows the `scalar-gemm` feature, so the thread-local
    /// take/put-back in `with_tl_scratch` can never flip a
    /// scalar-pinned build back to SIMD.
    fn default() -> Self {
        Self::new()
    }
}

impl GemmScratch {
    pub fn new() -> GemmScratch {
        GemmScratch {
            pack: PackBuf::new(),
            apack: PackBuf::new(),
            qa: PackBufI8::new(),
            scalar: cfg!(feature = "scalar-gemm"),
            act_max_override: None,
            observed_act_max: 0.0,
        }
    }

    /// A scratch pinned to the scalar reference kernels.
    pub fn scalar() -> GemmScratch {
        GemmScratch { scalar: true, ..GemmScratch::new() }
    }

    pub fn set_scalar(&mut self, scalar: bool) {
        self.scalar = scalar;
    }

    pub fn is_scalar(&self) -> bool {
        self.scalar
    }

    /// Arm the one-shot static activation-quantization override for the
    /// next int8 packed GEMM (see the field docs).
    pub fn set_act_max_override(&mut self, max_abs: Option<f32>) {
        self.act_max_override = max_abs;
    }

    /// Max-abs observed by the most recent int8 activation scan.
    pub fn observed_act_max(&self) -> f32 {
        self.observed_act_max
    }
}

thread_local! {
    /// Fallback workspace for entry points not handed a [`GemmScratch`].
    /// Taken out (not borrowed) for the duration of a call: a pool
    /// worker that *helps* while parked in its own GEMM's fork can
    /// re-enter gemm on this thread, and must get a fresh buffer rather
    /// than a RefCell panic.  The larger buffer wins the put-back.
    static TL_SCRATCH: RefCell<GemmScratch> = RefCell::new(GemmScratch::new());
}

fn with_tl_scratch<R>(f: impl FnOnce(&mut GemmScratch) -> R) -> R {
    let mut gs = TL_SCRATCH
        .try_with(|s| std::mem::take(&mut *s.borrow_mut()))
        .unwrap_or_default();
    let r = f(&mut gs);
    let _ = TL_SCRATCH.try_with(|s| {
        let mut slot = s.borrow_mut();
        if gs.pack.capacity_floats() >= slot.pack.capacity_floats() {
            *slot = gs;
        }
    });
    r
}

/// C = A (m×k) · B (k×n), auto-threaded.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(0, 0);
    matmul_into(a, b, &mut c);
    c
}

/// C = A·B into a reusable output buffer (resized in place, no
/// reallocation once its capacity suffices).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let t = plan_threads(a.rows, a.cols, b.cols, max_threads());
    matmul_view(MatView::full(a), MatView::full(b), c, t);
}

/// C = A (m×k) · Bᵀ where B is (n×k): dot products of rows, auto-threaded.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(0, 0);
    matmul_nt_into(a, b, &mut c);
    c
}

/// C = A·Bᵀ into a reusable output buffer.
pub fn matmul_nt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let t = plan_threads(a.rows, a.cols, b.rows, max_threads());
    matmul_nt_view(MatView::full(a), MatView::full(b), c, t);
}

/// C = A·B over strided views with an explicit worker cap (thread-local
/// packing scratch; hot paths use [`matmul_view_in`]).
pub fn matmul_view(a: MatView<'_>, b: MatView<'_>, c: &mut Mat, threads: usize) {
    with_tl_scratch(|gs| matmul_view_in(a, b, c, threads, gs));
}

/// C = A·Bᵀ over strided views with an explicit worker cap (thread-local
/// packing scratch; hot paths use [`matmul_nt_view_in`]).
pub fn matmul_nt_view(a: MatView<'_>, b: MatView<'_>, c: &mut Mat, threads: usize) {
    with_tl_scratch(|gs| matmul_nt_view_in(a, b, c, threads, gs));
}

/// `out[:, col0..col0+b.cols] = A·B` with a thread-local packing
/// scratch; hot paths use [`matmul_view_cols_in`].
pub fn matmul_view_cols(
    a: MatView<'_>,
    b: MatView<'_>,
    out: &mut Mat,
    col0: usize,
    threads: usize,
) {
    with_tl_scratch(|gs| matmul_view_cols_in(a, b, out, col0, threads, gs));
}

// lint: hot-path — the warm GEMM entry points: reused scratch only, no
// per-call heap traffic (pinned by tests/alloc_free.rs)
/// C = A·B over strided views with an explicit worker cap and caller
/// workspace.  `c` is resized (allocation-free after warmup) and fully
/// overwritten.  Above one worker the rows are partitioned into tasks on
/// the global [`pool`]; partitioning depends only on `threads`, so output
/// is bitwise identical for any pool size.
pub fn matmul_view_in(
    a: MatView<'_>,
    b: MatView<'_>,
    c: &mut Mat,
    threads: usize,
    gs: &mut GemmScratch,
) {
    matmul_epilogue_view_in(a, b, c, threads, gs, |_chunk, _row0| {});
}

/// [`matmul_view_in`] with the per-row-chunk **epilogue hook** (see
/// [`matmul_nt_epilogue_view_in`], where the hook contract is
/// documented): `epi(chunk, row0)` runs over each whole-row chunk
/// (width == stride == n) immediately after that chunk's kernel, inside
/// the same pool task — on the scalar path, the SIMD path, and the
/// packed-A tall-`m` path alike.  With `k == 0` the product contracts
/// to all-zeros and the hook still runs once over the zeroed output, so
/// fused semantics match the unfused sequence there too.
pub fn matmul_epilogue_view_in<'env, E>(
    a: MatView<'env>,
    b: MatView<'env>,
    c: &'env mut Mat,
    threads: usize,
    gs: &mut GemmScratch,
    epi: E,
) where
    E: Fn(&mut [f32], usize) + Send + Copy + 'env,
{
    assert_eq!(a.cols, b.rows, "matmul inner dims: {} vs {}", a.cols, b.rows);
    let (m, n, k) = (a.rows, b.cols, a.cols);
    if gs.scalar || k == 0 {
        // the scalar kernel accumulates into a zeroed C, and k == 0
        // contracts to all-zeros with no kernel pass at all
        c.reset(m, n);
        if m == 0 || n == 0 {
            return;
        }
        if k == 0 {
            epi(&mut c.data[..], 0);
            return;
        }
        run_row_chunks(&mut c.data, m, threads, n, move |chunk, row0| {
            mm_rows(a, b, chunk, row0);
            epi(chunk, row0);
        });
        return;
    }
    // SIMD path: every element is stored by a first-k-block tile whose
    // accumulators start at zero, so the O(m·n) zeroing pass is skipped
    c.resize_for_overwrite(m, n);
    if m == 0 || n == 0 {
        return;
    }
    let packed = kernel::pack_nn(&mut gs.pack, b);
    if m >= kernel::A_PACK_MIN_M {
        let apack = kernel::pack_a(&mut gs.apack, a);
        run_row_chunks_mr(&mut c.data, m, threads, n, move |chunk, row0| {
            kernel::gemm_chunk_pa(apack, row0, packed, k, n, chunk, n, 0);
            epi(chunk, row0);
        });
    } else {
        run_row_chunks(&mut c.data, m, threads, n, move |chunk, row0| {
            kernel::gemm_chunk(a, row0, packed, k, n, chunk, n, 0);
            epi(chunk, row0);
        });
    }
}

/// C = A·Bᵀ over strided views with an explicit worker cap and caller
/// workspace.  The transpose happens in the B-pack, so this is the same
/// microkernel as [`matmul_view_in`].
pub fn matmul_nt_view_in(
    a: MatView<'_>,
    b: MatView<'_>,
    c: &mut Mat,
    threads: usize,
    gs: &mut GemmScratch,
) {
    matmul_nt_epilogue_view_in(a, b, c, threads, gs, |_chunk, _row0| {});
}

/// `C = softmax_rows(scale · A·Bᵀ)` in one pass: the attention-logits
/// GEMM with the scale multiply and row-wise softmax **fused into the
/// row-chunk epilogue**.  Where the unfused sequence
/// (`matmul_nt_view_in` → `Mat::scale` → `softmax_rows`) re-streams the
/// whole m×n output twice after the fork-join barrier, here each row
/// chunk applies [`super::softmax_scaled_slice_rows`] immediately after
/// its kernel stores, while the rows are still cache-hot.
///
/// Bitwise identical to the unfused sequence for every thread cap and
/// chunking: the GEMM values are the plain kernels' values, chunks
/// partition the row set, and softmax is per-row — pinned by
/// `fused_softmax_matches_unfused_bitwise` here and by the release
/// `attn_prop` suite end-to-end.
pub fn matmul_nt_softmax_view_in(
    a: MatView<'_>,
    b: MatView<'_>,
    c: &mut Mat,
    scale: f32,
    threads: usize,
    gs: &mut GemmScratch,
) {
    let n = b.rows;
    matmul_nt_epilogue_view_in(a, b, c, threads, gs, move |chunk, _row0| {
        super::softmax_scaled_slice_rows(chunk, n, scale)
    });
}

/// The per-row-range **epilogue hook** on the `A·Bᵀ` entry points:
/// `epi(chunk, row0)` runs over each row chunk (whole rows,
/// width == stride == n) immediately after that chunk's GEMM kernel,
/// inside the same pool task.  Because chunks partition M and the hook
/// sees only complete rows, any per-row epilogue is invariant across
/// thread counts and chunkings (see docs/INVARIANTS.md).  With `k == 0`
/// the product contracts to all-zeros and the hook still runs once over
/// the zeroed output, so fused semantics match the unfused sequence
/// there too.
pub fn matmul_nt_epilogue_view_in<'env, E>(
    a: MatView<'env>,
    b: MatView<'env>,
    c: &'env mut Mat,
    threads: usize,
    gs: &mut GemmScratch,
    epi: E,
) where
    E: Fn(&mut [f32], usize) + Send + Copy + 'env,
{
    assert_eq!(a.cols, b.cols, "matmul_nt inner dims: {} vs {}", a.cols, b.cols);
    let (m, n, k) = (a.rows, b.rows, a.cols);
    if gs.scalar || k == 0 {
        c.reset(m, n);
        if m == 0 || n == 0 {
            return;
        }
        if k == 0 {
            epi(&mut c.data[..], 0);
            return;
        }
        run_row_chunks(&mut c.data, m, threads, n, move |chunk, row0| {
            mmnt_rows(a, b, chunk, row0);
            epi(chunk, row0);
        });
        return;
    }
    // fully overwritten by the microkernel — no zeroing pass needed
    c.resize_for_overwrite(m, n);
    if m == 0 || n == 0 {
        return;
    }
    let packed = kernel::pack_nt(&mut gs.pack, b);
    if m >= kernel::A_PACK_MIN_M {
        let apack = kernel::pack_a(&mut gs.apack, a);
        run_row_chunks_mr(&mut c.data, m, threads, n, move |chunk, row0| {
            kernel::gemm_chunk_pa(apack, row0, packed, k, n, chunk, n, 0);
            epi(chunk, row0);
        });
    } else {
        run_row_chunks(&mut c.data, m, threads, n, move |chunk, row0| {
            kernel::gemm_chunk(a, row0, packed, k, n, chunk, n, 0);
            epi(chunk, row0);
        });
    }
}

/// `out[:, col0..col0+b.cols] = A·B` — writes the product into a column
/// block of a wider row-major matrix (the per-head context slot), with no
/// intermediate buffer.  Rows outside the block are untouched.
pub fn matmul_view_cols_in(
    a: MatView<'_>,
    b: MatView<'_>,
    out: &mut Mat,
    col0: usize,
    threads: usize,
    gs: &mut GemmScratch,
) {
    matmul_view_cols_epilogue_in(a, b, out, col0, threads, gs, |_row, _r| {});
}

/// [`matmul_view_cols_in`] with the epilogue hook.  The output chunk is
/// *strided* here (the column block is a window of a wider matrix), so
/// the hook cannot receive the raw chunk — instead `epi(row, r)` runs
/// once per **live-width row** (`row.len() == b.cols`, global row index
/// `r`) immediately after that row's kernel stores.  Per-row invocation
/// is itself a whole-row chunking, so every chunking-invariant row
/// primitive composes unchanged.
pub fn matmul_view_cols_epilogue_in<'env, E>(
    a: MatView<'env>,
    b: MatView<'env>,
    out: &'env mut Mat,
    col0: usize,
    threads: usize,
    gs: &mut GemmScratch,
    epi: E,
) where
    E: Fn(&mut [f32], usize) + Send + Copy + 'env,
{
    assert_eq!(a.cols, b.rows, "matmul inner dims: {} vs {}", a.cols, b.rows);
    assert_eq!(a.rows, out.rows, "matmul_view_cols: row mismatch");
    assert!(col0 + b.cols <= out.cols, "matmul_view_cols: column overflow");
    let (m, stride, k, w) = (a.rows, out.cols, a.cols, b.cols);
    if m == 0 || w == 0 {
        return;
    }
    if gs.scalar {
        run_row_chunks(&mut out.data, m, threads, stride, move |chunk, row0| {
            mm_cols_rows(a, b, chunk, row0, col0, stride);
            for (i, row) in chunk.chunks_mut(stride).enumerate() {
                epi(&mut row[col0..col0 + w], row0 + i);
            }
        });
        return;
    }
    let packed = kernel::pack_nn(&mut gs.pack, b);
    run_row_chunks(&mut out.data, m, threads, stride, move |chunk, row0| {
        kernel::gemm_chunk(a, row0, packed, k, w, chunk, stride, col0);
        for (i, row) in chunk.chunks_mut(stride).enumerate() {
            epi(&mut row[col0..col0 + w], row0 + i);
        }
    });
}
// lint: end-hot-path

/// Weight dtype flavor for packed inference panels: full-precision f32
/// or symmetric per-output-channel int8 (see `kernel`'s int8 docs for
/// the quantization scheme).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Dtype {
    F32,
    Int8,
}

impl Dtype {
    /// Canonical lowercase name, as used in `serve.toml` and bench tags.
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Int8 => "int8",
        }
    }

    /// Parse a `serve.toml` / CLI dtype string.
    pub fn from_name(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "int8" | "i8" => Some(Dtype::Int8),
            _ => None,
        }
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One immutable pre-packed GEMM B-operand (a weight matrix), built
/// once per `Params` generation and consumed by
/// [`matmul_packed_view_in`] with no per-call packing.  The f32 flavor
/// stores the exact [`kernel::pack_nn`]/[`kernel::pack_nt`] image, so
/// consuming it is bitwise identical to packing per call; the int8
/// flavor stores the quantized image plus its per-output-channel
/// scales (indexed by packed column, `panels(n)·NR` entries).
#[derive(Debug)]
pub enum PackedPanels {
    F32 {
        buf: PackBuf,
        k: usize,
        n: usize,
    },
    Int8 {
        buf: PackBufI8,
        scales: Vec<f32>,
        k: usize,
        n: usize,
    },
}

impl PackedPanels {
    /// Pack a weight view for `C = A·B` (`transposed == false`, `b` is
    /// k×n) or `C = A·Bᵀ` (`transposed == true`, `b` is n×k — the
    /// orientation the tied-embedding MLM head consumes).
    pub fn pack(dtype: Dtype, b: MatView<'_>, transposed: bool) -> PackedPanels {
        let (k, n) = if transposed {
            (b.cols, b.rows)
        } else {
            (b.rows, b.cols)
        };
        match dtype {
            Dtype::F32 => {
                let mut buf = PackBuf::new();
                if transposed {
                    kernel::pack_nt(&mut buf, b);
                } else {
                    kernel::pack_nn(&mut buf, b);
                }
                PackedPanels::F32 { buf, k, n }
            }
            Dtype::Int8 => {
                let mut buf = PackBufI8::new();
                let mut scales = Vec::new();
                if transposed {
                    kernel::pack_nt_i8(&mut buf, &mut scales, b);
                } else {
                    kernel::pack_nn_i8(&mut buf, &mut scales, b);
                }
                PackedPanels::Int8 { buf, scales, k, n }
            }
        }
    }

    /// Inner (accumulation) dimension of the packed operand.
    pub fn k(&self) -> usize {
        match self {
            PackedPanels::F32 { k, .. } | PackedPanels::Int8 { k, .. } => *k,
        }
    }

    /// Output-column count of the packed operand.
    pub fn n(&self) -> usize {
        match self {
            PackedPanels::F32 { n, .. } | PackedPanels::Int8 { n, .. } => *n,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            PackedPanels::F32 { .. } => Dtype::F32,
            PackedPanels::Int8 { .. } => Dtype::Int8,
        }
    }

    /// Packed image size in bytes (cache accounting).
    pub fn bytes(&self) -> usize {
        let elems = kernel::panels(self.n()) * self.k() * kernel::NR;
        match self {
            PackedPanels::F32 { .. } => elems * 4,
            PackedPanels::Int8 { scales, .. } => elems + scales.len() * 4,
        }
    }
}

/// C = A·W against a pre-packed weight operand: the per-call B-pack
/// (for the tied-embedding MLM head, a whole (vocab × d)
/// transpose-pack) is gone, so warm callers do **zero** weight packing
/// or quantization work.  The f32 flavor routes through the exact
/// kernels of [`matmul_view_in`]/[`matmul_nt_view_in`] (bitwise
/// identical, including the packed-A tall-`m` path); the int8 flavor
/// quantizes A per tensor into `gs` and dequantizes in the kernel
/// epilogue — bitwise thread-count-deterministic because integer
/// accumulation is exact.  Always runs the microkernel: panels are its
/// format, so a scalar-pinned `gs` is not honoured here (callers
/// wanting the scalar baseline use the unpacked entry points).
// lint: hot-path — the cached-panel serving path: all packing was paid
// at cache build; a warm call touches only reused scratch
pub fn matmul_packed_view_in(
    a: MatView<'_>,
    w: &PackedPanels,
    c: &mut Mat,
    threads: usize,
    gs: &mut GemmScratch,
) {
    matmul_packed_epilogue_view_in(a, w, c, threads, gs, |_chunk, _row0| {});
}

/// [`matmul_packed_view_in`] with the epilogue hook.  On the int8
/// flavor the hook composes with the kernel's dequant epilogue: the
/// chunk handed to `epi` already holds dequantized f32 values, so the
/// same row primitives serve both dtypes.  With `k == 0` the hook runs
/// once over the zeroed output like every other entry point.
pub fn matmul_packed_epilogue_view_in<'env, E>(
    a: MatView<'env>,
    w: &'env PackedPanels,
    c: &'env mut Mat,
    threads: usize,
    gs: &mut GemmScratch,
    epi: E,
) where
    E: Fn(&mut [f32], usize) + Send + Copy + 'env,
{
    assert_eq!(
        a.cols,
        w.k(),
        "matmul_packed inner dims: {} vs {}",
        a.cols,
        w.k()
    );
    let (m, n, k) = (a.rows, w.n(), w.k());
    if k == 0 {
        c.reset(m, n);
        if m > 0 && n > 0 {
            epi(&mut c.data[..], 0);
        }
        return;
    }
    c.resize_for_overwrite(m, n);
    if m == 0 || n == 0 {
        return;
    }
    match w {
        PackedPanels::F32 { buf, .. } => {
            let packed = buf.flat(kernel::panels(n) * k * kernel::NR);
            if m >= kernel::A_PACK_MIN_M {
                let apack = kernel::pack_a(&mut gs.apack, a);
                run_row_chunks_mr(&mut c.data, m, threads, n, move |chunk, row0| {
                    kernel::gemm_chunk_pa(apack, row0, packed, k, n, chunk, n, 0);
                    epi(chunk, row0);
                });
            } else {
                run_row_chunks(&mut c.data, m, threads, n, move |chunk, row0| {
                    kernel::gemm_chunk(a, row0, packed, k, n, chunk, n, 0);
                    epi(chunk, row0);
                });
            }
        }
        PackedPanels::Int8 { buf, scales, .. } => {
            let packed = buf.flat(kernel::panels(n) * k * kernel::NR);
            let (aq, a_scale) = quantize_acts(gs, a);
            let scales = scales.as_slice();
            run_row_chunks(&mut c.data, m, threads, n, move |chunk, row0| {
                kernel::gemm_chunk_i8(
                    aq, row0, packed, k, n, a_scale, scales, chunk, n, 0,
                );
                epi(chunk, row0);
            });
        }
    }
}

/// Activation quantization for the int8 packed path: honours (and
/// consumes) the one-shot static-scale override, falling back to the
/// dynamic max-abs scan — whose observed magnitude is recorded for the
/// encoder's calibration EWMA.
fn quantize_acts<'a>(gs: &'a mut GemmScratch, a: MatView<'_>) -> (&'a [i8], f32) {
    match gs.act_max_override.take() {
        Some(max_abs) => {
            kernel::quantize_activations_with_max(&mut gs.qa, a, max_abs)
        }
        None => {
            let (aq, a_scale) = kernel::quantize_activations(&mut gs.qa, a);
            gs.observed_act_max = a_scale * 127.0;
            (aq, a_scale)
        }
    }
}

// The **aux-buffer epilogue** entry points: the residual flavour of the
// hook.  `epi(c_chunk, x_chunk, [h_chunk,] row0)` receives the GEMM
// output chunk read-only plus the *same row range* of one or two
// auxiliary m×n buffers mutably — how `x += c + bias` (and the next
// block's `h = layer_norm(x)`) runs inside the GEMM's own fork, with
// `chunks_mut` guaranteeing the row ranges are disjoint across tasks.
// The invariance argument is unchanged: chunks partition M identically
// across all buffers, and the hook is pure per-row.

/// C = A·B with the two-buffer aux epilogue (see above): `x` is m×n,
/// split at the same row boundaries as C.  With `k == 0` the hook runs
/// once over the zeroed product.
pub fn matmul_aux_epilogue_view_in<'env, E>(
    a: MatView<'env>,
    b: MatView<'env>,
    c: &'env mut Mat,
    x: &'env mut [f32],
    threads: usize,
    gs: &mut GemmScratch,
    epi: E,
) where
    E: Fn(&[f32], &mut [f32], usize) + Send + Copy + 'env,
{
    assert_eq!(a.cols, b.rows, "matmul inner dims: {} vs {}", a.cols, b.rows);
    let (m, n, k) = (a.rows, b.cols, a.cols);
    assert_eq!(x.len(), m * n, "aux buffer shape mismatch");
    if gs.scalar || k == 0 {
        c.reset(m, n);
        if m == 0 || n == 0 {
            return;
        }
        if k == 0 {
            epi(&c.data[..], x, 0);
            return;
        }
        run_row_chunks2(&mut c.data, x, m, threads, n, false, move |cc, xc, row0| {
            mm_rows(a, b, cc, row0);
            epi(cc, xc, row0);
        });
        return;
    }
    c.resize_for_overwrite(m, n);
    if m == 0 || n == 0 {
        return;
    }
    let packed = kernel::pack_nn(&mut gs.pack, b);
    if m >= kernel::A_PACK_MIN_M {
        let apack = kernel::pack_a(&mut gs.apack, a);
        run_row_chunks2(&mut c.data, x, m, threads, n, true, move |cc, xc, row0| {
            kernel::gemm_chunk_pa(apack, row0, packed, k, n, cc, n, 0);
            epi(cc, xc, row0);
        });
    } else {
        run_row_chunks2(&mut c.data, x, m, threads, n, false, move |cc, xc, row0| {
            kernel::gemm_chunk(a, row0, packed, k, n, cc, n, 0);
            epi(cc, xc, row0);
        });
    }
}

/// C = A·B with the three-buffer aux epilogue: `x` and `h` are m×n,
/// split at the same row boundaries as C.
pub fn matmul_aux2_epilogue_view_in<'env, E>(
    a: MatView<'env>,
    b: MatView<'env>,
    c: &'env mut Mat,
    x: &'env mut [f32],
    h: &'env mut [f32],
    threads: usize,
    gs: &mut GemmScratch,
    epi: E,
) where
    E: Fn(&[f32], &mut [f32], &mut [f32], usize) + Send + Copy + 'env,
{
    assert_eq!(a.cols, b.rows, "matmul inner dims: {} vs {}", a.cols, b.rows);
    let (m, n, k) = (a.rows, b.cols, a.cols);
    assert_eq!(x.len(), m * n, "aux buffer shape mismatch");
    assert_eq!(h.len(), m * n, "aux buffer shape mismatch");
    if gs.scalar || k == 0 {
        c.reset(m, n);
        if m == 0 || n == 0 {
            return;
        }
        if k == 0 {
            epi(&c.data[..], x, h, 0);
            return;
        }
        run_row_chunks3(
            &mut c.data,
            x,
            h,
            m,
            threads,
            n,
            false,
            move |cc, xc, hc, row0| {
                mm_rows(a, b, cc, row0);
                epi(cc, xc, hc, row0);
            },
        );
        return;
    }
    c.resize_for_overwrite(m, n);
    if m == 0 || n == 0 {
        return;
    }
    let packed = kernel::pack_nn(&mut gs.pack, b);
    if m >= kernel::A_PACK_MIN_M {
        let apack = kernel::pack_a(&mut gs.apack, a);
        run_row_chunks3(
            &mut c.data,
            x,
            h,
            m,
            threads,
            n,
            true,
            move |cc, xc, hc, row0| {
                kernel::gemm_chunk_pa(apack, row0, packed, k, n, cc, n, 0);
                epi(cc, xc, hc, row0);
            },
        );
    } else {
        run_row_chunks3(
            &mut c.data,
            x,
            h,
            m,
            threads,
            n,
            false,
            move |cc, xc, hc, row0| {
                kernel::gemm_chunk(a, row0, packed, k, n, cc, n, 0);
                epi(cc, xc, hc, row0);
            },
        );
    }
}

/// C = A·W (pre-packed weight panels) with the two-buffer aux epilogue.
/// On int8 panels the hook composes with the dequant epilogue, exactly
/// like [`matmul_packed_epilogue_view_in`].
pub fn matmul_packed_aux_epilogue_view_in<'env, E>(
    a: MatView<'env>,
    w: &'env PackedPanels,
    c: &'env mut Mat,
    x: &'env mut [f32],
    threads: usize,
    gs: &mut GemmScratch,
    epi: E,
) where
    E: Fn(&[f32], &mut [f32], usize) + Send + Copy + 'env,
{
    assert_eq!(
        a.cols,
        w.k(),
        "matmul_packed inner dims: {} vs {}",
        a.cols,
        w.k()
    );
    let (m, n, k) = (a.rows, w.n(), w.k());
    assert_eq!(x.len(), m * n, "aux buffer shape mismatch");
    if k == 0 {
        c.reset(m, n);
        if m > 0 && n > 0 {
            epi(&c.data[..], x, 0);
        }
        return;
    }
    c.resize_for_overwrite(m, n);
    if m == 0 || n == 0 {
        return;
    }
    match w {
        PackedPanels::F32 { buf, .. } => {
            let packed = buf.flat(kernel::panels(n) * k * kernel::NR);
            if m >= kernel::A_PACK_MIN_M {
                let apack = kernel::pack_a(&mut gs.apack, a);
                run_row_chunks2(
                    &mut c.data,
                    x,
                    m,
                    threads,
                    n,
                    true,
                    move |cc, xc, row0| {
                        kernel::gemm_chunk_pa(apack, row0, packed, k, n, cc, n, 0);
                        epi(cc, xc, row0);
                    },
                );
            } else {
                run_row_chunks2(
                    &mut c.data,
                    x,
                    m,
                    threads,
                    n,
                    false,
                    move |cc, xc, row0| {
                        kernel::gemm_chunk(a, row0, packed, k, n, cc, n, 0);
                        epi(cc, xc, row0);
                    },
                );
            }
        }
        PackedPanels::Int8 { buf, scales, .. } => {
            let packed = buf.flat(kernel::panels(n) * k * kernel::NR);
            let (aq, a_scale) = quantize_acts(gs, a);
            let scales = scales.as_slice();
            run_row_chunks2(
                &mut c.data,
                x,
                m,
                threads,
                n,
                false,
                move |cc, xc, row0| {
                    kernel::gemm_chunk_i8(
                        aq, row0, packed, k, n, a_scale, scales, cc, n, 0,
                    );
                    epi(cc, xc, row0);
                },
            );
        }
    }
}

/// C = A·W (pre-packed weight panels) with the three-buffer aux
/// epilogue.
pub fn matmul_packed_aux2_epilogue_view_in<'env, E>(
    a: MatView<'env>,
    w: &'env PackedPanels,
    c: &'env mut Mat,
    x: &'env mut [f32],
    h: &'env mut [f32],
    threads: usize,
    gs: &mut GemmScratch,
    epi: E,
) where
    E: Fn(&[f32], &mut [f32], &mut [f32], usize) + Send + Copy + 'env,
{
    assert_eq!(
        a.cols,
        w.k(),
        "matmul_packed inner dims: {} vs {}",
        a.cols,
        w.k()
    );
    let (m, n, k) = (a.rows, w.n(), w.k());
    assert_eq!(x.len(), m * n, "aux buffer shape mismatch");
    assert_eq!(h.len(), m * n, "aux buffer shape mismatch");
    if k == 0 {
        c.reset(m, n);
        if m > 0 && n > 0 {
            epi(&c.data[..], x, h, 0);
        }
        return;
    }
    c.resize_for_overwrite(m, n);
    if m == 0 || n == 0 {
        return;
    }
    match w {
        PackedPanels::F32 { buf, .. } => {
            let packed = buf.flat(kernel::panels(n) * k * kernel::NR);
            if m >= kernel::A_PACK_MIN_M {
                let apack = kernel::pack_a(&mut gs.apack, a);
                run_row_chunks3(
                    &mut c.data,
                    x,
                    h,
                    m,
                    threads,
                    n,
                    true,
                    move |cc, xc, hc, row0| {
                        kernel::gemm_chunk_pa(apack, row0, packed, k, n, cc, n, 0);
                        epi(cc, xc, hc, row0);
                    },
                );
            } else {
                run_row_chunks3(
                    &mut c.data,
                    x,
                    h,
                    m,
                    threads,
                    n,
                    false,
                    move |cc, xc, hc, row0| {
                        kernel::gemm_chunk(a, row0, packed, k, n, cc, n, 0);
                        epi(cc, xc, hc, row0);
                    },
                );
            }
        }
        PackedPanels::Int8 { buf, scales, .. } => {
            let packed = buf.flat(kernel::panels(n) * k * kernel::NR);
            let (aq, a_scale) = quantize_acts(gs, a);
            let scales = scales.as_slice();
            run_row_chunks3(
                &mut c.data,
                x,
                h,
                m,
                threads,
                n,
                false,
                move |cc, xc, hc, row0| {
                    kernel::gemm_chunk_i8(
                        aq, row0, packed, k, n, a_scale, scales, cc, n, 0,
                    );
                    epi(cc, xc, hc, row0);
                },
            );
        }
    }
}

/// Pool-striped standalone elementwise pass: split `data` (`m` rows of
/// width `stride`) into up to `threads` whole-row stripes and run
/// `f(chunk, row0)` over each on the global pool.  This is the shape of
/// every *surviving* post-GEMM pass (the epilogue-fusion-off regimes,
/// the embedding-stage layer norm): same whole-row chunking as the GEMM
/// epilogue, so for any chunking-invariant row primitive the result is
/// bitwise identical to one serial call at any thread count — and no
/// O(m·n) pass runs single-threaded while the pool sits idle.
pub fn stripe_rows<'env, F>(
    data: &'env mut [f32],
    m: usize,
    threads: usize,
    stride: usize,
    f: F,
) where
    F: Fn(&mut [f32], usize) + Send + Copy + 'env,
{
    if m == 0 || stride == 0 {
        return;
    }
    run_row_chunks(data, m, threads, stride, f);
}

/// Two-buffer flavour of [`stripe_rows`] for `dst = f(src)` passes
/// (the one-pass `layer_norm_rows_into` copy-and-normalize): `dst` and
/// `src` are both `m` rows of width `stride`, split at the same row
/// boundaries.
pub fn stripe_rows2<'env, F>(
    dst: &'env mut [f32],
    src: &'env [f32],
    m: usize,
    threads: usize,
    stride: usize,
    f: F,
) where
    F: Fn(&mut [f32], &[f32], usize) + Send + Copy + 'env,
{
    debug_assert_eq!(dst.len(), src.len());
    if m == 0 || stride == 0 {
        return;
    }
    let t = threads.clamp(1, m);
    if t == 1 {
        f(dst, src, 0);
        return;
    }
    let rows_per = (m + t - 1) / t;
    // lint: allow-start(hot-path-alloc) — same per-fork task boxes as
    // run_row_chunks above
    let tasks: Vec<pool::Task<'env>> = dst
        .chunks_mut(rows_per * stride)
        .zip(src.chunks(rows_per * stride))
        .enumerate()
        .map(|(w, (dc, sc))| {
            Box::new(move || f(dc, sc, w * rows_per)) as pool::Task<'env>
        })
        .collect();
    // lint: allow-end(hot-path-alloc)
    pool::global().run(tasks);
}
// lint: end-hot-path

/// Compare two kernel outputs: **bitwise** in the default build; within
/// `ulps` units-in-last-place under the `fma` cargo feature, whose
/// fused multiply-add changes each accumulation step by one rounding
/// (callers budget a couple of ULPs per `k` step).  Lives here rather
/// than in a test module so the integration suites
/// (`tests/kernel_prop.rs`) share one definition.
pub fn assert_f32s_match(got: &[f32], want: &[f32], ulps: u32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    #[cfg(not(feature = "fma"))]
    {
        let _ = ulps;
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!(
                g.to_bits() == w.to_bits(),
                "{ctx}: [{i}] {g} != {w} (bitwise)"
            );
        }
    }
    #[cfg(feature = "fma")]
    {
        // map bits to a monotone integer line so ULP distance is a
        // subtraction; ±0 and NaN↔NaN pairs short-circuit as equal
        fn ordered(x: f32) -> i64 {
            let b = x.to_bits();
            if b & 0x8000_0000 != 0 {
                -i64::from(b & 0x7fff_ffff)
            } else {
                i64::from(b)
            }
        }
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            if g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan()) {
                continue;
            }
            let dist = (ordered(*g) - ordered(*w)).unsigned_abs();
            assert!(
                dist <= u64::from(ulps),
                "{ctx}: [{i}] {g} vs {w} is {dist} ULPs (budget {ulps})"
            );
        }
    }
}

// lint: hot-path — the shared fork-join shape; only the documented
// per-fork task boxes below may allocate
/// Split `data` (m rows of width `stride`) into up to `threads`
/// contiguous row blocks and run `kernel(chunk, row0)` over each as
/// tasks on the global [`pool`] — the one fork-join shape every GEMM
/// variant shares.  `threads == 1` runs inline on the caller (the
/// serial fast path).  Chunking depends only on `threads`, and each
/// chunk is produced by the same serial kernel either way, so outputs
/// are bitwise identical for any pool size.
fn run_row_chunks<'env, K>(
    data: &'env mut [f32],
    m: usize,
    threads: usize,
    stride: usize,
    kernel: K,
) where
    K: Fn(&mut [f32], usize) + Send + Copy + 'env,
{
    let t = threads.clamp(1, m);
    if t == 1 {
        kernel(data, 0);
        return;
    }
    let rows_per = (m + t - 1) / t;
    // lint: allow-start(hot-path-alloc) — the parallel regime's
    // documented allocations: one boxed closure per pool task plus the
    // task vec (see tests/alloc_free.rs; the serial t == 1 path above
    // is the zero-alloc regime)
    let tasks: Vec<pool::Task<'env>> = data
        .chunks_mut(rows_per * stride)
        .enumerate()
        .map(|(w, chunk)| {
            Box::new(move || kernel(chunk, w * rows_per)) as pool::Task<'env>
        })
        .collect();
    // lint: allow-end(hot-path-alloc)
    pool::global().run(tasks);
}

/// [`run_row_chunks`] with the row split rounded up to [`kernel::MR`]
/// so every chunk's global row offset is MR-aligned — the packed-A
/// kernel's row panels then coincide with chunk-local tiles.  Chunk
/// boundaries never affect values (each row's accumulation is
/// self-contained), so the rounded split is as bitwise-stable as the
/// plain one.
fn run_row_chunks_mr<'env, K>(
    data: &'env mut [f32],
    m: usize,
    threads: usize,
    stride: usize,
    kernel: K,
) where
    K: Fn(&mut [f32], usize) + Send + Copy + 'env,
{
    let t = threads.clamp(1, m);
    if t == 1 {
        kernel(data, 0);
        return;
    }
    let rows_per = (m + t - 1) / t;
    let rows_per = (rows_per + kernel::MR - 1) / kernel::MR * kernel::MR;
    // lint: allow-start(hot-path-alloc) — same per-fork task boxes as
    // run_row_chunks above
    let tasks: Vec<pool::Task<'env>> = data
        .chunks_mut(rows_per * stride)
        .enumerate()
        .map(|(w, chunk)| {
            Box::new(move || kernel(chunk, w * rows_per)) as pool::Task<'env>
        })
        .collect();
    // lint: allow-end(hot-path-alloc)
    pool::global().run(tasks);
}

/// [`run_row_chunks`] over **three lockstep buffers**: `c` (the GEMM
/// output), `x` and `h` are all m rows of width `stride`, split at the
/// same row boundaries (optionally [`kernel::MR`]-aligned for the
/// packed-A kernel), so each pool task owns the *same* row range of all
/// three.  This is how the residual epilogue gets mutable access to
/// disjoint rows of the residual stream and the next block's normalized
/// input without any aliasing: `chunks_mut` hands out non-overlapping
/// slices, no unsafe required.
fn run_row_chunks3<'env, K>(
    c: &'env mut [f32],
    x: &'env mut [f32],
    h: &'env mut [f32],
    m: usize,
    threads: usize,
    stride: usize,
    mr_align: bool,
    kernel: K,
) where
    K: Fn(&mut [f32], &mut [f32], &mut [f32], usize) + Send + Copy + 'env,
{
    debug_assert_eq!(c.len(), m * stride);
    debug_assert_eq!(x.len(), m * stride);
    debug_assert_eq!(h.len(), m * stride);
    let t = threads.clamp(1, m);
    if t == 1 {
        kernel(c, x, h, 0);
        return;
    }
    let mut rows_per = (m + t - 1) / t;
    if mr_align {
        rows_per = (rows_per + kernel::MR - 1) / kernel::MR * kernel::MR;
    }
    // lint: allow-start(hot-path-alloc) — same per-fork task boxes as
    // run_row_chunks above
    let tasks: Vec<pool::Task<'env>> = c
        .chunks_mut(rows_per * stride)
        .zip(x.chunks_mut(rows_per * stride))
        .zip(h.chunks_mut(rows_per * stride))
        .enumerate()
        .map(|(w, ((cc, xc), hc))| {
            Box::new(move || kernel(cc, xc, hc, w * rows_per))
                as pool::Task<'env>
        })
        .collect();
    // lint: allow-end(hot-path-alloc)
    pool::global().run(tasks);
}

/// Two-buffer flavour of [`run_row_chunks3`] (no `h` stream — the
/// final-layer residual epilogue norms `x` in place).
fn run_row_chunks2<'env, K>(
    c: &'env mut [f32],
    x: &'env mut [f32],
    m: usize,
    threads: usize,
    stride: usize,
    mr_align: bool,
    kernel: K,
) where
    K: Fn(&mut [f32], &mut [f32], usize) + Send + Copy + 'env,
{
    debug_assert_eq!(c.len(), m * stride);
    debug_assert_eq!(x.len(), m * stride);
    let t = threads.clamp(1, m);
    if t == 1 {
        kernel(c, x, 0);
        return;
    }
    let mut rows_per = (m + t - 1) / t;
    if mr_align {
        rows_per = (rows_per + kernel::MR - 1) / kernel::MR * kernel::MR;
    }
    // lint: allow-start(hot-path-alloc) — same per-fork task boxes as
    // run_row_chunks above
    let tasks: Vec<pool::Task<'env>> = c
        .chunks_mut(rows_per * stride)
        .zip(x.chunks_mut(rows_per * stride))
        .enumerate()
        .map(|(w, (cc, xc))| {
            Box::new(move || kernel(cc, xc, w * rows_per)) as pool::Task<'env>
        })
        .collect();
    // lint: allow-end(hot-path-alloc)
    pool::global().run(tasks);
}

// ---------------------------------------------------------------------
// Scalar reference kernels — the pre-SIMD path, kept as the measured
// baseline (`--features scalar-gemm` / `GemmScratch::scalar`) and as the
// bitwise oracle for the microkernel's A·B accumulation order.  They use
// *frozen verbatim copies* of the pre-change `axpy`/`dot` inner loops
// (below), so the "scalar" records in the benches really measure the
// pre-change kernel's numerics and codegen, not a re-vectorised
// stand-in.
// ---------------------------------------------------------------------

/// Frozen pre-SIMD `axpy` (manual 8-wide unroll): the scalar-baseline
/// kernels' inner loop, byte-for-byte what shipped before the
/// microkernel.  Internal-only; the kernels always pass equal lengths.
#[inline]
fn axpy_scalar_ref(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    for c in 0..chunks {
        let o = c * 8;
        // manual unroll — the autovectorizer turns this into fma lanes
        y[o] += alpha * x[o];
        y[o + 1] += alpha * x[o + 1];
        y[o + 2] += alpha * x[o + 2];
        y[o + 3] += alpha * x[o + 3];
        y[o + 4] += alpha * x[o + 4];
        y[o + 5] += alpha * x[o + 5];
        y[o + 6] += alpha * x[o + 6];
        y[o + 7] += alpha * x[o + 7];
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// Frozen pre-SIMD `dot` (4 split accumulators) — see
/// [`axpy_scalar_ref`].  The public [`dot`] changed accumulation shape
/// (one 8-lane accumulator), so the baseline keeps its own copy.
#[inline]
fn dot_scalar_ref(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    let chunks = n / 4;
    for c in 0..chunks {
        let o = c * 4;
        s0 += x[o] * y[o];
        s1 += x[o + 1] * y[o + 1];
        s2 += x[o + 2] * y[o + 2];
        s3 += x[o + 3] * y[o + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..n {
        tail += x[i] * y[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// Serial blocked kernel over output rows `row0..row0 + c.len()/n` of A·B.
/// `c` is the contiguous, zeroed output block for those rows.
fn mm_rows(a: MatView<'_>, b: MatView<'_>, c: &mut [f32], row0: usize) {
    let k = a.cols;
    let n = b.cols;
    let rows = c.len() / n;
    for i0 in (0..rows).step_by(BLOCK_M) {
        let i1 = (i0 + BLOCK_M).min(rows);
        for k0 in (0..k).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(k);
            for j0 in (0..n).step_by(BLOCK_N) {
                let j1 = (j0 + BLOCK_N).min(n);
                for i in i0..i1 {
                    let arow = a.row(row0 + i);
                    let crow = &mut c[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        // no zero-skip: 0.0 * NaN must stay NaN
                        axpy_scalar_ref(
                            arow[kk],
                            &b.row(kk)[j0..j1],
                            &mut crow[j0..j1],
                        );
                    }
                }
            }
        }
    }
}

/// Serial kernel over output rows of A·Bᵀ.
fn mmnt_rows(a: MatView<'_>, b: MatView<'_>, c: &mut [f32], row0: usize) {
    let n = b.rows;
    let rows = c.len() / n;
    for i in 0..rows {
        let arow = a.row(row0 + i);
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = dot_scalar_ref(arow, b.row(j));
        }
    }
}

/// Serial kernel writing A·B into columns `[col0, col0+b.cols)` of a
/// stride-`stride` output block.
fn mm_cols_rows(
    a: MatView<'_>,
    b: MatView<'_>,
    chunk: &mut [f32],
    row0: usize,
    col0: usize,
    stride: usize,
) {
    let rows = chunk.len() / stride;
    let w = b.cols;
    for i in 0..rows {
        let arow = a.row(row0 + i);
        let base = i * stride + col0;
        let crow = &mut chunk[base..base + w];
        crow.fill(0.0);
        for (kk, &av) in arow.iter().enumerate() {
            axpy_scalar_ref(av, b.row(kk), crow);
        }
    }
}

// ---------------------------------------------------------------------
// Lane-based vector primitives
// ---------------------------------------------------------------------

/// y += alpha * x, 8-lane vectorised with a scalar remainder.
///
/// **Contract: `x.len() == y.len()`**, enforced unconditionally (a
/// single predictable branch): these used to compute over
/// `min(x.len(), y.len())`, which turned upstream shape bugs into
/// silently wrong numbers instead of a panic — in *either* direction,
/// so a debug-only check on one side would not be enough.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(
        x.len(),
        y.len(),
        "axpy length mismatch: x has {}, y has {}",
        x.len(),
        y.len()
    );
    let n = x.len();
    let y = &mut y[..n];
    let av = F32x8::splat(alpha);
    let chunks = n / LANES;
    for c in 0..chunks {
        let o = c * LANES;
        let xv = F32x8::load(&x[o..]);
        let yv = F32x8::load(&y[o..]);
        xv.mul_add(av, yv).store(&mut y[o..]);
    }
    for i in chunks * LANES..n {
        y[i] += alpha * x[i];
    }
}

/// Dot product: one 8-lane accumulator (fixed-tree horizontal sum) plus
/// an in-order scalar remainder.
///
/// **Contract: `x.len() == y.len()`**, enforced unconditionally — same
/// rationale as [`axpy`].
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(
        x.len(),
        y.len(),
        "dot length mismatch: x has {}, y has {}",
        x.len(),
        y.len()
    );
    let n = x.len();
    let y = &y[..n];
    let chunks = n / LANES;
    let mut acc = F32x8::ZERO;
    for c in 0..chunks {
        let o = c * LANES;
        acc = F32x8::load(&x[o..]).mul_add(F32x8::load(&y[o..]), acc);
    }
    let mut tail = 0.0f32;
    for i in chunks * LANES..n {
        tail += x[i] * y[i];
    }
    acc.hsum() + tail
}
// lint: end-hot-path

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += f64::from(a.at(i, k)) * f64::from(b.at(k, j));
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    fn rand_mat(rng: &mut Pcg32, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(&mut m.data, 1.0);
        m
    }

    #[test]
    fn matmul_matches_naive_on_odd_shapes() {
        let mut rng = Pcg32::seeded(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (65, 130, 70)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "({m},{k},{n}): {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn microkernel_edge_tiles_match_naive() {
        // every (m, n, k) below the MR/NR/LANES tile sizes, plus shapes
        // straddling one tile boundary — the edge paths of the kernel
        let mut rng = Pcg32::seeded(31);
        let dims = [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17];
        for &m in &dims {
            for &n in &dims {
                for &k in &[1usize, 2, 7, 8, 9] {
                    let a = rand_mat(&mut rng, m, k);
                    let b = rand_mat(&mut rng, k, n);
                    let want = naive(&a, &b);
                    let got = matmul(&a, &b);
                    assert!(
                        got.max_abs_diff(&want) < 1e-4,
                        "NN ({m},{k},{n}): {}",
                        got.max_abs_diff(&want)
                    );
                    let bt = b.transpose();
                    let mut nt = Mat::zeros(0, 0);
                    matmul_nt_view(
                        MatView::full(&a),
                        MatView::full(&bt),
                        &mut nt,
                        1,
                    );
                    assert!(
                        nt.max_abs_diff(&want) < 1e-4,
                        "NT ({m},{k},{n}): {}",
                        nt.max_abs_diff(&want)
                    );
                }
            }
        }
    }

    #[test]
    fn simd_matches_scalar_bitwise() {
        // the microkernel replays the scalar kernel's exact per-element
        // operation sequence on the A·B paths (ascending k, unfused
        // mul-add, one accumulator) — so outputs are bitwise equal, not
        // merely close.  Under the `fma` feature the SIMD side fuses its
        // multiply-adds, so the comparison relaxes to a ULP budget
        // (~2 per k step) via assert_f32s_match; the default build still
        // pins exact bit equality.
        let mut rng = Pcg32::seeded(32);
        for &(m, k, n) in
            &[(1, 1, 1), (3, 5, 7), (16, 16, 16), (17, 33, 9), (65, 300, 70)]
        {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let (av, bv) = (MatView::full(&a), MatView::full(&b));
            let budget = (2 * k + 16) as u32;
            let mut simd = Mat::zeros(0, 0);
            let mut scal = Mat::zeros(0, 0);
            let mut gs = GemmScratch::new();
            gs.set_scalar(false);
            matmul_view_in(av, bv, &mut simd, 1, &mut gs);
            matmul_view_in(av, bv, &mut scal, 1, &mut GemmScratch::scalar());
            assert_f32s_match(
                &simd.data,
                &scal.data,
                budget,
                &format!("NN ({m},{k},{n})"),
            );
            // the column-block variant shares the kernel
            let mut wide_simd = Mat::filled_with(m, n + 5, |_, _| 9.0);
            let mut wide_scal = wide_simd.clone();
            matmul_view_cols_in(av, bv, &mut wide_simd, 3, 1, &mut gs);
            matmul_view_cols_in(
                av,
                bv,
                &mut wide_scal,
                3,
                1,
                &mut GemmScratch::scalar(),
            );
            assert_f32s_match(
                &wide_simd.data,
                &wide_scal.data,
                budget,
                &format!("cols ({m},{k},{n})"),
            );
        }
    }

    #[test]
    fn nt_simd_matches_scalar_within_tolerance() {
        // the NT path changed accumulation shape (packed panels vs the
        // old 4-way split dot), so scalar and SIMD agree to rounding,
        // both anchored to the f64 reference
        let mut rng = Pcg32::seeded(33);
        let a = rand_mat(&mut rng, 13, 21);
        let b = rand_mat(&mut rng, 17, 21);
        let want = naive(&a, &b.transpose());
        let mut simd = Mat::zeros(0, 0);
        let mut scal = Mat::zeros(0, 0);
        let mut gs = GemmScratch::new();
        gs.set_scalar(false);
        matmul_nt_view_in(MatView::full(&a), MatView::full(&b), &mut simd, 1, &mut gs);
        matmul_nt_view_in(
            MatView::full(&a),
            MatView::full(&b),
            &mut scal,
            1,
            &mut GemmScratch::scalar(),
        );
        assert!(simd.max_abs_diff(&want) < 1e-4);
        assert!(scal.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn threaded_matches_serial_bitwise() {
        let mut rng = Pcg32::seeded(9);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (65, 130, 70), (64, 64, 64)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let (av, bv) = (MatView::full(&a), MatView::full(&b));
            let mut serial = Mat::zeros(0, 0);
            matmul_view(av, bv, &mut serial, 1);
            for threads in [2, 3, 4, 7] {
                let mut par = Mat::zeros(0, 0);
                matmul_view(av, bv, &mut par, threads);
                assert_eq!(
                    serial.data, par.data,
                    "({m},{k},{n}) with {threads} threads is not bitwise equal"
                );
            }
            // same property for the transposed kernel
            let bt = rand_mat(&mut rng, n, k);
            let btv = MatView::full(&bt);
            let mut serial = Mat::zeros(0, 0);
            matmul_nt_view(av, btv, &mut serial, 1);
            for threads in [2, 5] {
                let mut par = Mat::zeros(0, 0);
                matmul_nt_view(av, btv, &mut par, threads);
                assert_eq!(serial.data, par.data);
            }
        }
    }

    #[test]
    fn nan_propagates_through_zero_entries() {
        // A has a 0.0 exactly where B carries NaN / Inf: the product must
        // be NaN (0·NaN = NaN, 0·Inf = NaN) — the old zero-skip ate it.
        let a = Mat::from_vec(1, 2, vec![0.0, 1.0]);
        let b = Mat::from_vec(2, 2, vec![f32::NAN, f32::INFINITY, 3.0, 4.0]);
        let c = matmul(&a, &b);
        assert!(c.at(0, 0).is_nan(), "NaN dropped: {}", c.at(0, 0));
        assert!(c.at(0, 1).is_nan(), "Inf·0 dropped: {}", c.at(0, 1));
    }

    #[test]
    fn matmul_into_reuses_buffer() {
        let mut rng = Pcg32::seeded(4);
        let a = rand_mat(&mut rng, 9, 11);
        let b = rand_mat(&mut rng, 11, 5);
        let mut c = Mat::zeros(0, 0);
        matmul_into(&a, &b, &mut c);
        let want = c.clone();
        let ptr = c.data.as_ptr();
        let cap = c.data.capacity();
        // stale garbage in the buffer must not leak into the next product
        c.data.iter_mut().for_each(|x| *x = f32::NAN);
        matmul_into(&a, &b, &mut c);
        assert_eq!(c.data, want.data);
        assert_eq!(c.data.as_ptr(), ptr, "buffer was reallocated");
        assert_eq!(c.data.capacity(), cap);
    }

    #[test]
    fn simd_path_overwrites_stale_garbage_without_a_zeroing_pass() {
        // the SIMD entry points skip the O(m·n) reset: every element
        // must still be stored over, including across shape changes
        // that leave NaN garbage in the reused buffer's prefix
        let mut rng = Pcg32::seeded(15);
        let mut gs = GemmScratch::new();
        gs.set_scalar(false);
        let mut c = Mat::zeros(0, 0);
        for &(m, k, n) in &[(9, 7, 11), (3, 5, 4), (21, 2, 17)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            c.data.iter_mut().for_each(|x| *x = f32::NAN);
            matmul_view_in(MatView::full(&a), MatView::full(&b), &mut c, 1, &mut gs);
            assert_eq!((c.rows, c.cols), (m, n));
            // f32::max ignores NaN, so max_abs_diff alone can't catch a
            // leaked NaN — check finiteness explicitly first
            assert!(
                c.data.iter().all(|x| x.is_finite()),
                "NN ({m},{k},{n}) leaked stale garbage"
            );
            let want = naive(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-4, "NN ({m},{k},{n})");
            let bt = rand_mat(&mut rng, n, k);
            c.data.iter_mut().for_each(|x| *x = f32::NAN);
            matmul_nt_view_in(MatView::full(&a), MatView::full(&bt), &mut c, 1, &mut gs);
            assert!(
                c.data.iter().all(|x| x.is_finite()),
                "NT ({m},{k},{n}) leaked stale garbage"
            );
        }
    }

    #[test]
    fn pack_scratch_is_reused_across_calls() {
        // a caller-owned GemmScratch must reach steady state: same pack
        // allocation for repeated same-shape products
        let mut rng = Pcg32::seeded(14);
        let a = rand_mat(&mut rng, 12, 20);
        let b = rand_mat(&mut rng, 20, 24);
        let mut c = Mat::zeros(0, 0);
        let mut gs = GemmScratch::new();
        gs.set_scalar(false);
        matmul_view_in(MatView::full(&a), MatView::full(&b), &mut c, 1, &mut gs);
        let ptr = gs.pack.as_ptr();
        let cap = gs.pack.capacity_floats();
        for _ in 0..3 {
            matmul_view_in(MatView::full(&a), MatView::full(&b), &mut c, 1, &mut gs);
            assert_eq!(gs.pack.as_ptr(), ptr, "pack buffer reallocated");
            assert_eq!(gs.pack.capacity_floats(), cap);
        }
    }

    #[test]
    fn strided_views_match_materialized_slices() {
        let mut rng = Pcg32::seeded(5);
        let packed = rand_mat(&mut rng, 13, 12); // 3 heads × 4 cols
        let other = rand_mat(&mut rng, 13, 4);
        for head in 0..3 {
            let view = MatView::cols(&packed, head * 4, 4);
            let copy = view.to_mat();
            assert_eq!(copy.rows, 13);
            assert_eq!(copy.cols, 4);
            // view GEMM == owned GEMM, bitwise
            let mut from_view = Mat::zeros(0, 0);
            matmul_nt_view(view, MatView::full(&other), &mut from_view, 1);
            let want = matmul_nt(&copy, &other);
            assert_eq!(from_view.data, want.data);
        }
    }

    #[test]
    fn view_cols_writes_only_its_block() {
        let mut rng = Pcg32::seeded(6);
        let logits = rand_mat(&mut rng, 7, 5);
        let v = rand_mat(&mut rng, 5, 3);
        let want = matmul(&logits, &v);
        let mut ctx = Mat::filled_with(7, 10, |_, _| 99.0);
        for threads in [1, 3] {
            matmul_view_cols(
                MatView::full(&logits),
                MatView::full(&v),
                &mut ctx,
                4,
                threads,
            );
            for r in 0..7 {
                for c in 0..3 {
                    assert_eq!(ctx.at(r, 4 + c), want.at(r, c));
                }
                assert_eq!(ctx.at(r, 0), 99.0, "wrote outside the block");
                assert_eq!(ctx.at(r, 9), 99.0, "wrote outside the block");
            }
        }
    }

    #[test]
    fn plan_threads_keeps_small_gemms_serial() {
        assert_eq!(plan_threads(32, 16, 16, 8), 1);
        assert!(plan_threads(512, 512, 512, 8) > 1);
        // never more workers than rows
        assert_eq!(plan_threads(2, 4096, 4096, 8), 2);
        // a GEMM just past the threshold gets a partial fan-out, not the
        // whole budget
        let m = 16;
        let kn = 512;
        let flops = 2 * m * kn * kn;
        assert!(flops >= PAR_FLOP_THRESHOLD && flops < 2 * PAR_FLOP_THRESHOLD);
        let t = plan_threads(m, kn, kn, 64);
        assert!(t > 1 && t <= 8, "marginal GEMM over-fanned: {t}");
    }

    #[test]
    fn matmul_nt_equals_matmul_with_transpose() {
        let mut rng = Pcg32::seeded(1);
        let a = rand_mat(&mut rng, 13, 21);
        let b = rand_mat(&mut rng, 17, 21);
        let got = matmul_nt(&a, &b);
        let want = matmul(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg32::seeded(2);
        let a = rand_mat(&mut rng, 8, 8);
        assert!(matmul(&a, &Mat::eye(8)).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&Mat::eye(8), &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn dot_matches_reference() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (0..37).map(|i| (37 - i) as f32).collect();
        let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - want).abs() < 1e-2);
    }

    #[test]
    fn axpy_and_dot_cover_every_remainder_lane() {
        // every length 0..=2·LANES: full vectors, the scalar tail, and
        // the empty case — axpy bitwise vs the scalar recurrence (ULP
        // budget under `fma`, which fuses the lane mul-adds), dot
        // against an f64 reference
        for n in 0..=2 * LANES {
            let x: Vec<f32> = (0..n).map(|i| (i as f32 + 1.0) * 0.25).collect();
            let mut y: Vec<f32> = (0..n).map(|i| i as f32 - 3.0).collect();
            let mut want = y.clone();
            for i in 0..n {
                want[i] += 1.5 * x[i];
            }
            axpy(1.5, &x, &mut y);
            assert_f32s_match(&y, &want, 2, &format!("axpy len {n}"));

            let z: Vec<f32> = (0..n).map(|i| 0.5 - i as f32).collect();
            let want: f64 = x
                .iter()
                .zip(&z)
                .map(|(a, b)| f64::from(*a) * f64::from(*b))
                .sum();
            assert!(
                (f64::from(dot(&x, &z)) - want).abs() < 1e-3,
                "dot len {n}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "axpy length mismatch")]
    fn axpy_length_mismatch_panics() {
        let x = [1.0f32; 5];
        let mut y = [0.0f32; 4];
        axpy(2.0, &x, &mut y);
    }

    #[test]
    #[should_panic(expected = "dot length mismatch")]
    fn dot_length_mismatch_panics() {
        // the short-x direction — exactly the case a debug-only or
        // slice-based check would let slide in release builds
        dot(&[1.0, 2.0], &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "matmul inner dims")]
    fn shape_mismatch_panics() {
        matmul(&Mat::zeros(2, 3), &Mat::zeros(4, 2));
    }

    #[test]
    fn thread_env_zero_falls_back_to_default() {
        let (t, valid) = parse_thread_env("0", 8);
        assert_eq!(t, 8);
        assert!(!valid, "0 must be rejected, not become a degenerate plan");
    }

    #[test]
    fn thread_env_garbage_falls_back_to_default() {
        for raw in ["abc", "", "-3", "4.5", "1e3"] {
            let (t, valid) = parse_thread_env(raw, 6);
            assert_eq!(t, 6, "raw {raw:?}");
            assert!(!valid, "raw {raw:?} must be rejected");
        }
    }

    #[test]
    fn thread_env_valid_values_pass_through() {
        assert_eq!(parse_thread_env("4", 8), (4, true));
        assert_eq!(parse_thread_env(" 16 ", 8), (16, true));
    }

    #[test]
    fn pool_gemm_matches_serial_for_any_chunking() {
        // same property as threaded_matches_serial_bitwise, phrased
        // against the pool explicitly: however the rows are chunked into
        // pool tasks, output is bitwise identical to the serial kernel
        let mut rng = Pcg32::seeded(21);
        let a = rand_mat(&mut rng, 37, 53);
        let b = rand_mat(&mut rng, 53, 29);
        let (av, bv) = (MatView::full(&a), MatView::full(&b));
        let mut serial = Mat::zeros(0, 0);
        matmul_view(av, bv, &mut serial, 1);
        for chunks in [2, 8, 37, 64] {
            let mut pooled = Mat::zeros(0, 0);
            matmul_view(av, bv, &mut pooled, chunks);
            assert_eq!(serial.data, pooled.data, "{chunks} chunks diverged");
        }
    }

    #[test]
    fn fused_softmax_matches_unfused_bitwise() {
        // the epilogue-fused logits entry must be indistinguishable, bit
        // for bit, from matmul_nt → Mat::scale → softmax_rows for every
        // kernel (SIMD, packed-A tall-m, scalar), every thread plan, and
        // the k == 0 degenerate (all-zero logits → uniform rows) — the
        // invariant the head-parallel attention rewrite stands on
        let mut rng = Pcg32::seeded(51);
        for &(m, n, k) in
            &[(1, 1, 1), (7, 9, 5), (33, 17, 12), (50, 21, 24), (4, 6, 0)]
        {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, n, k);
            let (av, bv) = (MatView::full(&a), MatView::full(&b));
            let scale = 1.0 / (k.max(1) as f32).sqrt();
            for scalar in [false, true] {
                let mut gs = if scalar {
                    GemmScratch::scalar()
                } else {
                    let mut gs = GemmScratch::new();
                    gs.set_scalar(false);
                    gs
                };
                let mut want = Mat::zeros(0, 0);
                matmul_nt_view_in(av, bv, &mut want, 1, &mut gs);
                want.scale(scale);
                crate::linalg::softmax_rows(&mut want);
                for threads in [1usize, 2, 3, 7] {
                    let mut got = Mat::zeros(0, 0);
                    matmul_nt_softmax_view_in(
                        av, bv, &mut got, scale, threads, &mut gs,
                    );
                    assert_eq!((got.rows, got.cols), (m, n));
                    for (i, (g, w)) in
                        got.data.iter().zip(&want.data).enumerate()
                    {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "({m},{n},{k}) scalar={scalar} t={threads} \
                             elem {i}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dtype_names_round_trip() {
        for d in [Dtype::F32, Dtype::Int8] {
            assert_eq!(Dtype::from_name(d.name()), Some(d));
            assert_eq!(format!("{d}"), d.name());
        }
        assert_eq!(Dtype::from_name("i8"), Some(Dtype::Int8));
        assert_eq!(Dtype::from_name("fp16"), None);
    }

    #[test]
    fn packed_f32_panels_match_per_call_pack_bitwise() {
        // consuming a cached f32 panel must be indistinguishable from
        // packing per call — including tall shapes that take the
        // packed-A path and the k == 0 degenerate contract
        let mut rng = Pcg32::seeded(41);
        for &(m, k, n) in
            &[(1, 3, 5), (17, 33, 9), (50, 20, 40), (65, 130, 70), (4, 0, 6)]
        {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let (av, bv) = (MatView::full(&a), MatView::full(&b));
            let mut gs = GemmScratch::new();
            gs.set_scalar(false);
            let mut want = Mat::zeros(0, 0);
            matmul_view_in(av, bv, &mut want, 1, &mut gs);
            let p = PackedPanels::pack(Dtype::F32, bv, false);
            assert_eq!((p.k(), p.n(), p.dtype()), (k, n, Dtype::F32));
            let mut got = Mat::zeros(0, 0);
            matmul_packed_view_in(av, &p, &mut got, 1, &mut gs);
            assert_eq!(got.data, want.data, "NN ({m},{k},{n})");
            // NT orientation (the MLM-head shape)
            let bt = rand_mat(&mut rng, n, k);
            let btv = MatView::full(&bt);
            let mut want = Mat::zeros(0, 0);
            matmul_nt_view_in(av, btv, &mut want, 1, &mut gs);
            let p = PackedPanels::pack(Dtype::F32, btv, true);
            assert_eq!((p.k(), p.n()), (k, n));
            let mut got = Mat::zeros(0, 0);
            matmul_packed_view_in(av, &p, &mut got, 1, &mut gs);
            assert_eq!(got.data, want.data, "NT ({m},{k},{n})");
        }
    }

    /// Independent replay of the documented int8 spec: per-column f32
    /// scales from max |.|, round/clamp quantization, exact i64 integer
    /// accumulation, one dequant multiply — must agree **bitwise** with
    /// the kernel.
    fn naive_int8(a: &Mat, b: &Mat) -> Mat {
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let q = |v: f32, inv: f32| -> i64 {
            ((v * inv).round().clamp(-127.0, 127.0) as i8) as i64
        };
        let mut a_max = 0.0f32;
        for &v in &a.data {
            a_max = a_max.max(v.abs());
        }
        let (a_scale, a_inv) = if a_max > 0.0 {
            (a_max / 127.0, 127.0 / a_max)
        } else {
            (0.0, 0.0)
        };
        let mut c = Mat::zeros(m, n);
        for j in 0..n {
            let mut b_max = 0.0f32;
            for kk in 0..k {
                b_max = b_max.max(b.at(kk, j).abs());
            }
            let (scale, inv) = if b_max > 0.0 {
                (b_max / 127.0, 127.0 / b_max)
            } else {
                (0.0, 0.0)
            };
            for i in 0..m {
                let mut acc = 0i64;
                for kk in 0..k {
                    acc += q(a.at(i, kk), a_inv) * q(b.at(kk, j), inv);
                }
                *c.at_mut(i, j) = acc as f32 * (a_scale * scale);
            }
        }
        c
    }

    #[test]
    fn packed_int8_matches_spec_reference_bitwise() {
        let mut rng = Pcg32::seeded(42);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (65, 40, 21)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let p = PackedPanels::pack(Dtype::Int8, MatView::full(&b), false);
            assert_eq!(p.dtype(), Dtype::Int8);
            let mut got = Mat::zeros(0, 0);
            let mut gs = GemmScratch::new();
            gs.set_scalar(false);
            matmul_packed_view_in(MatView::full(&a), &p, &mut got, 1, &mut gs);
            let want = naive_int8(&a, &b);
            for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "int8 ({m},{k},{n}) elem {i}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn packed_int8_thread_and_chunk_deterministic() {
        // integer accumulation is exact, so any thread plan must be
        // bitwise identical to serial — the int8 determinism guarantee
        let mut rng = Pcg32::seeded(43);
        let a = rand_mat(&mut rng, 53, 37);
        let b = rand_mat(&mut rng, 37, 29);
        let p = PackedPanels::pack(Dtype::Int8, MatView::full(&b), false);
        let mut gs = GemmScratch::new();
        gs.set_scalar(false);
        let mut serial = Mat::zeros(0, 0);
        matmul_packed_view_in(MatView::full(&a), &p, &mut serial, 1, &mut gs);
        for threads in [2, 3, 7, 53] {
            let mut par = Mat::zeros(0, 0);
            matmul_packed_view_in(
                MatView::full(&a),
                &p,
                &mut par,
                threads,
                &mut gs,
            );
            assert_eq!(serial.data, par.data, "t={threads} diverged");
        }
    }

    #[test]
    fn int8_quantization_error_is_bounded() {
        // dequantized int8 approximates the f32 product within the
        // analytic bound: per-step error ≤ (|a|·s_b + |b|·s_a)/2, summed
        // over k — asserted at 2× slack
        let mut rng = Pcg32::seeded(44);
        let (m, k, n) = (9, 31, 13);
        let a = rand_mat(&mut rng, m, k);
        let b = rand_mat(&mut rng, k, n);
        let p = PackedPanels::pack(Dtype::Int8, MatView::full(&b), false);
        let mut gs = GemmScratch::new();
        gs.set_scalar(false);
        let mut got = Mat::zeros(0, 0);
        matmul_packed_view_in(MatView::full(&a), &p, &mut got, 1, &mut gs);
        let want = naive(&a, &b);
        let a_max = a.data.iter().fold(0.0f32, |s, v| s.max(v.abs()));
        let b_max = b.data.iter().fold(0.0f32, |s, v| s.max(v.abs()));
        let bound = k as f32 * a_max * b_max / 127.0 * 2.0 + 1e-6;
        assert!(
            got.max_abs_diff(&want) <= bound,
            "int8 error {} above bound {bound}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn a_panel_rounded_chunking_stays_bitwise() {
        // m = 50 crosses A_PACK_MIN_M: thread splits round up to MR, and
        // every plan must still be bitwise equal to serial
        let mut rng = Pcg32::seeded(45);
        let a = rand_mat(&mut rng, 50, 24);
        let b = rand_mat(&mut rng, 24, 33);
        let (av, bv) = (MatView::full(&a), MatView::full(&b));
        assert!(a.rows >= kernel::A_PACK_MIN_M);
        let mut serial = Mat::zeros(0, 0);
        matmul_view(av, bv, &mut serial, 1);
        for threads in [2, 3, 7, 13] {
            let mut par = Mat::zeros(0, 0);
            matmul_view(av, bv, &mut par, threads);
            assert_eq!(serial.data, par.data, "t={threads}");
        }
        // and the packed-A path agrees bitwise with the scalar oracle
        let mut scal = Mat::zeros(0, 0);
        matmul_view_in(av, bv, &mut scal, 1, &mut GemmScratch::scalar());
        assert_f32s_match(&scal.data, &serial.data, 64, "packed-A vs scalar");
    }

    #[test]
    fn fused_epilogue_matches_two_pass_bitwise_on_every_entry() {
        // tentpole invariant: one affine per-row hook, every entry point
        // × kernel × thread plan; shapes cross A_PACK_MIN_M and include
        // the k == 0 degenerate (hook over the zeroed product).  The
        // reference applies the *same* hook as one serial whole-matrix
        // pass after a plain GEMM — whole-row chunks + pure per-row hook
        // ⇒ bitwise equality at any chunking.
        let mut rng = Pcg32::seeded(61);
        for &(m, k, n) in
            &[(1, 1, 1), (7, 5, 9), (33, 12, 17), (50, 24, 21), (4, 0, 6)]
        {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let bt = rand_mat(&mut rng, n, k);
            let (av, bv, btv) =
                (MatView::full(&a), MatView::full(&b), MatView::full(&bt));
            let epi = move |chunk: &mut [f32], row0: usize| {
                for (i, row) in chunk.chunks_mut(n).enumerate() {
                    let r = (row0 + i) as f32 + 1.0;
                    for (j, x) in row.iter_mut().enumerate() {
                        *x = *x * 0.5 + r + j as f32 * 0.25;
                    }
                }
            };
            for scalar in [false, true] {
                let mut gs = if scalar {
                    GemmScratch::scalar()
                } else {
                    let mut gs = GemmScratch::new();
                    gs.set_scalar(false);
                    gs
                };
                let mut want = Mat::zeros(0, 0);
                matmul_view_in(av, bv, &mut want, 1, &mut gs);
                epi(&mut want.data[..], 0);
                for threads in [1usize, 2, 3, 7] {
                    let mut got = Mat::zeros(0, 0);
                    matmul_epilogue_view_in(
                        av, bv, &mut got, threads, &mut gs, epi,
                    );
                    assert_eq!(
                        got.data, want.data,
                        "NN ({m},{k},{n}) scalar={scalar} t={threads}"
                    );
                }
                let mut want = Mat::zeros(0, 0);
                matmul_nt_view_in(av, btv, &mut want, 1, &mut gs);
                epi(&mut want.data[..], 0);
                for threads in [1usize, 3, 7] {
                    let mut got = Mat::zeros(0, 0);
                    matmul_nt_epilogue_view_in(
                        av, btv, &mut got, threads, &mut gs, epi,
                    );
                    assert_eq!(
                        got.data, want.data,
                        "NT ({m},{k},{n}) scalar={scalar} t={threads}"
                    );
                }
                // column-window of a wider matrix: the hook runs per
                // live-width row instead of per chunk
                let blank = Mat::filled_with(m, n + 5, |_, _| 9.0);
                let mut want = blank.clone();
                matmul_view_cols_in(av, bv, &mut want, 3, 1, &mut gs);
                for r in 0..m {
                    epi(&mut want.data[r * (n + 5) + 3..][..n], r);
                }
                for threads in [1usize, 2, 7] {
                    let mut got = blank.clone();
                    matmul_view_cols_epilogue_in(
                        av, bv, &mut got, 3, threads, &mut gs, epi,
                    );
                    assert_eq!(
                        got.data, want.data,
                        "cols ({m},{k},{n}) scalar={scalar} t={threads}"
                    );
                }
            }
            // cached panels always run the microkernel — no scalar loop;
            // on int8 the hook composes with the dequant epilogue
            let mut gs = GemmScratch::new();
            gs.set_scalar(false);
            for dtype in [Dtype::F32, Dtype::Int8] {
                let p = PackedPanels::pack(dtype, bv, false);
                let mut want = Mat::zeros(0, 0);
                matmul_packed_view_in(av, &p, &mut want, 1, &mut gs);
                epi(&mut want.data[..], 0);
                for threads in [1usize, 2, 7] {
                    let mut got = Mat::zeros(0, 0);
                    matmul_packed_epilogue_view_in(
                        av, &p, &mut got, threads, &mut gs, epi,
                    );
                    assert_eq!(
                        got.data, want.data,
                        "packed {dtype} ({m},{k},{n}) t={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn aux_epilogue_entries_match_striped_two_pass_bitwise() {
        // the residual-flavour hooks: x += c + f(row) (aux) plus
        // h = 2·x + ½ (aux2), run inside the GEMM fork vs as one serial
        // pass after a plain GEMM — bitwise equal on every kernel,
        // thread plan, and dtype, including the k == 0 degenerate and
        // the MR-rounded packed-A chunking (m = 50)
        let mut rng = Pcg32::seeded(62);
        for &(m, k, n) in &[(3, 5, 4), (50, 24, 21), (4, 0, 6)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let (av, bv) = (MatView::full(&a), MatView::full(&b));
            let mut x0 = vec![0.0f32; m * n];
            let mut h0 = vec![0.0f32; m * n];
            rng.fill_normal(&mut x0, 1.0);
            rng.fill_normal(&mut h0, 1.0);
            let epi2 = move |cc: &[f32], xc: &mut [f32], row0: usize| {
                for (i, (crow, xrow)) in
                    cc.chunks(n).zip(xc.chunks_mut(n)).enumerate()
                {
                    let r = (row0 + i) as f32 * 0.125;
                    for (xv, cv) in xrow.iter_mut().zip(crow) {
                        *xv += *cv + r;
                    }
                }
            };
            let epi3 =
                move |cc: &[f32], xc: &mut [f32], hc: &mut [f32], row0: usize| {
                    epi2(cc, xc, row0);
                    for (hv, xv) in hc.iter_mut().zip(&*xc) {
                        *hv = *xv * 2.0 + 0.5;
                    }
                };
            for scalar in [false, true] {
                let mut gs = if scalar {
                    GemmScratch::scalar()
                } else {
                    let mut gs = GemmScratch::new();
                    gs.set_scalar(false);
                    gs
                };
                let mut cref = Mat::zeros(0, 0);
                matmul_view_in(av, bv, &mut cref, 1, &mut gs);
                let mut xw = x0.clone();
                let mut hw = h0.clone();
                if m > 0 && n > 0 {
                    epi3(&cref.data, &mut xw, &mut hw, 0);
                }
                for threads in [1usize, 2, 3, 7] {
                    let ctx = format!(
                        "aux ({m},{k},{n}) scalar={scalar} t={threads}"
                    );
                    let (mut c2, mut x2) = (Mat::zeros(0, 0), x0.clone());
                    matmul_aux_epilogue_view_in(
                        av, bv, &mut c2, &mut x2, threads, &mut gs, epi2,
                    );
                    assert_eq!(c2.data, cref.data, "{ctx}: c");
                    assert_eq!(x2, xw, "{ctx}: x");
                    let (mut c3, mut x3, mut h3) =
                        (Mat::zeros(0, 0), x0.clone(), h0.clone());
                    matmul_aux2_epilogue_view_in(
                        av, bv, &mut c3, &mut x3, &mut h3, threads, &mut gs,
                        epi3,
                    );
                    assert_eq!(c3.data, cref.data, "{ctx}: aux2 c");
                    assert_eq!(x3, xw, "{ctx}: aux2 x");
                    assert_eq!(h3, hw, "{ctx}: aux2 h");
                }
            }
            // cached panels (microkernel only; int8 composes the hook
            // with the dequant epilogue)
            let mut gs = GemmScratch::new();
            gs.set_scalar(false);
            for dtype in [Dtype::F32, Dtype::Int8] {
                let p = PackedPanels::pack(dtype, bv, false);
                let mut cref = Mat::zeros(0, 0);
                matmul_packed_view_in(av, &p, &mut cref, 1, &mut gs);
                let mut xw = x0.clone();
                let mut hw = h0.clone();
                if m > 0 && n > 0 {
                    epi3(&cref.data, &mut xw, &mut hw, 0);
                }
                for threads in [1usize, 3, 7] {
                    let ctx =
                        format!("packed-aux {dtype} ({m},{k},{n}) t={threads}");
                    let (mut c2, mut x2) = (Mat::zeros(0, 0), x0.clone());
                    matmul_packed_aux_epilogue_view_in(
                        av, &p, &mut c2, &mut x2, threads, &mut gs, epi2,
                    );
                    assert_eq!(c2.data, cref.data, "{ctx}: c");
                    assert_eq!(x2, xw, "{ctx}: x");
                    let (mut c3, mut x3, mut h3) =
                        (Mat::zeros(0, 0), x0.clone(), h0.clone());
                    matmul_packed_aux2_epilogue_view_in(
                        av, &p, &mut c3, &mut x3, &mut h3, threads, &mut gs,
                        epi3,
                    );
                    assert_eq!(c3.data, cref.data, "{ctx}: aux2 c");
                    assert_eq!(x3, xw, "{ctx}: aux2 x");
                    assert_eq!(h3, hw, "{ctx}: aux2 h");
                }
            }
        }
    }

    #[test]
    fn act_max_override_is_one_shot_and_scale_exact() {
        // a static override armed with the dynamic scan's own max must be
        // bitwise invisible (identical scale → identical quantization),
        // and the override must be consumed by exactly one GEMM — no
        // leak into the next int8 call
        let mut rng = Pcg32::seeded(63);
        let a = rand_mat(&mut rng, 9, 31);
        let b = rand_mat(&mut rng, 31, 13);
        let p = PackedPanels::pack(Dtype::Int8, MatView::full(&b), false);
        let mut gs = GemmScratch::new();
        gs.set_scalar(false);
        let mut want = Mat::zeros(0, 0);
        matmul_packed_view_in(MatView::full(&a), &p, &mut want, 1, &mut gs);
        let a_max = a.data.iter().fold(0.0f32, |s, v| s.max(v.abs()));
        // the dynamic scan reported its magnitude for the encoder's EWMA
        // (scale round-trips through /127·127, so compare with slack)
        let obs = gs.observed_act_max();
        assert!(
            (obs - a_max).abs() <= a_max * 1e-5,
            "observed {obs} vs scanned {a_max}"
        );
        gs.set_act_max_override(Some(a_max));
        let mut got = Mat::zeros(0, 0);
        matmul_packed_view_in(MatView::full(&a), &p, &mut got, 1, &mut gs);
        assert_eq!(got.data, want.data, "static scale == dynamic max diverged");
        // consumed: the next call rescans dynamically, same result
        let mut again = Mat::zeros(0, 0);
        matmul_packed_view_in(MatView::full(&a), &p, &mut again, 1, &mut gs);
        assert_eq!(again.data, want.data, "override leaked into second call");
        // a tighter cap saturates instead of rescaling: quantizing with
        // half the true max clamps the peak element at ±127
        let mut dbuf = PackBufI8::new();
        let (q_dyn, s_dyn) =
            kernel::quantize_activations(&mut dbuf, MatView::full(&a));
        let mut cbuf = PackBufI8::new();
        let (q_cap, s_cap) = kernel::quantize_activations_with_max(
            &mut cbuf,
            MatView::full(&a),
            a_max * 0.5,
        );
        assert!(s_cap < s_dyn, "capped scale {s_cap} not below {s_dyn}");
        assert_eq!(q_dyn.len(), q_cap.len());
        assert_eq!(
            q_cap.iter().map(|&v| (v as i32).abs()).max(),
            Some(127),
            "peak element did not saturate under the tight cap"
        );
    }
}
