//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments.  A valued flag may repeat — [`Args::get`] returns the last
//! occurrence (the legacy override behavior), [`Args::all`] returns every
//! one in order (what repeatable flags like `repro serve --model a=…
//! --model b=…` read).  Unknown flags are an error, so typos fail fast.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, Vec<String>>,
    positional: Vec<String>,
    known: Vec<(String, String)>, // (name, help)
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown flag --{0}")]
    Unknown(String),
    #[error("flag --{0} expects a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    Invalid(String, String),
}

impl Args {
    /// Parse `argv` against a declared flag set `[(name, help)]`.
    /// Flags declared with a trailing `!` are boolean (no value).
    pub fn parse(
        argv: impl IntoIterator<Item = String>,
        spec: &[(&str, &str)],
    ) -> Result<Args, CliError> {
        let mut args = Args {
            known: spec
                .iter()
                .map(|(n, h)| (n.to_string(), h.to_string()))
                .collect(),
            ..Args::default()
        };
        let bools: Vec<&str> = spec
            .iter()
            .filter(|(n, _)| n.ends_with('!'))
            .map(|(n, _)| n.trim_end_matches('!'))
            .collect();
        let valued: Vec<&str> = spec
            .iter()
            .filter(|(n, _)| !n.ends_with('!'))
            .map(|(n, _)| *n)
            .collect();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if bools.contains(&key.as_str()) {
                    args.flags.entry(key).or_default().push("true".into());
                } else if valued.contains(&key.as_str()) {
                    let val = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError::MissingValue(key.clone()))?,
                    };
                    args.flags.entry(key).or_default().push(val);
                } else {
                    return Err(CliError::Unknown(key));
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Last occurrence of a flag (repeats override, the legacy rule).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .get(key)
            .and_then(|v| v.last())
            .map(String::as_str)
    }

    /// Every occurrence of a repeatable flag, in argv order.
    pub fn all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(key.into(), v.into())),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(key.into(), v.into())),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Usage text from the declared spec.
    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("usage: {prog} [flags]\n");
        for (name, help) in &self.known {
            let display = if name.ends_with('!') {
                format!("--{}", name.trim_end_matches('!'))
            } else {
                format!("--{name} <value>")
            };
            s.push_str(&format!("  {display:28} {help}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    const SPEC: &[(&str, &str)] = &[
        ("steps", "number of steps"),
        ("lr", "learning rate"),
        ("verbose!", "chatty"),
    ];

    #[test]
    fn parses_valued_and_bool_flags() {
        let a = Args::parse(
            argv(&["--steps", "100", "--verbose", "--lr=0.1", "pos1"]),
            SPEC,
        )
        .unwrap();
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.1);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1"]);
    }

    #[test]
    fn repeated_flags_collect_in_order_and_last_wins_for_get() {
        let a = Args::parse(
            argv(&["--steps", "1", "--steps=2", "--steps", "3"]),
            SPEC,
        )
        .unwrap();
        assert_eq!(a.all("steps"), vec!["1", "2", "3"]);
        assert_eq!(a.get("steps"), Some("3"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 3);
        assert!(a.all("lr").is_empty());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(argv(&[]), SPEC).unwrap();
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(
            Args::parse(argv(&["--nope"]), SPEC),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            Args::parse(argv(&["--steps"]), SPEC),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn invalid_value_rejected() {
        let a = Args::parse(argv(&["--steps", "abc"]), SPEC).unwrap();
        assert!(matches!(
            a.usize_or("steps", 0),
            Err(CliError::Invalid(_, _))
        ));
    }

    #[test]
    fn usage_lists_flags() {
        let a = Args::parse(argv(&[]), SPEC).unwrap();
        let u = a.usage("repro");
        assert!(u.contains("--steps <value>"));
        assert!(u.contains("--verbose"));
    }
}
