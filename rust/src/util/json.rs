//! Minimal self-contained JSON parser/serializer.
//!
//! The offline build has no `serde_json`, so the artifact manifest
//! (`artifacts/manifest.json`, written by `python/compile/aot.py`) is parsed
//! with this hand-rolled recursive-descent parser.  It supports the full
//! JSON grammar (RFC 8259) minus `\u` surrogate-pair edge cases beyond the
//! BMP, which the manifest never contains.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field access; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Array index access; returns `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|v| v.get(i)).unwrap_or(&NULL)
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut vec = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(vec));
        }
        loop {
            vec.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(vec)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16));
                            match c {
                                Some(d) => code = code * 16 + d,
                                None => return self.err("bad \\u escape"),
                            }
                        }
                        match char::from_u32(code) {
                            Some(c) => out.push(c),
                            None => return self.err("bad codepoint"),
                        }
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return self.err("invalid utf-8 lead byte"),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    match std::str::from_utf8(&self.bytes[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err("invalid number"),
        }
    }
}

/// Parse a JSON document (must consume the full input modulo whitespace).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing data after JSON value");
    }
    Ok(v)
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write(self, &mut s);
        f.write_str(&s)
    }
}

/// Serialize compactly (stable key order — Obj is a BTreeMap).
pub fn write(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write(val, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builders used by the metrics/reporting code.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(0).as_usize(), Some(1));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\nb\t\"q\" A é""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A é"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\"}").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,null,true],"nested":{"k":"v"},"s":"a\"b"}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn missing_key_is_null() {
        let v = parse("{}").unwrap();
        assert!(v.get("nope").is_null());
        assert!(v.idx(3).is_null());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Json::Num(1359490.0);
        assert_eq!(v.to_string(), "1359490");
    }
}
