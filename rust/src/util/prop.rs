//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Pcg32`]; the runner executes it
//! for `cases` independent seeds and, on failure, re-raises with the
//! failing seed so the case can be replayed deterministically:
//!
//! ```ignore
//! prop_check("batcher never exceeds max_batch", 200, |rng| {
//!     let reqs = gen_requests(rng);
//!     ...
//!     assert!(batch.len() <= max);
//! });
//! ```

use super::rng::Pcg32;

/// Run `property` for `cases` seeds; panics with the failing seed attached.
pub fn prop_check(name: &str, cases: u64, property: impl Fn(&mut Pcg32)) {
    // Honor PROP_SEED for replaying a single failing case.
    if let Ok(seed) = std::env::var("PROP_SEED") {
        let seed: u64 = seed.parse().expect("PROP_SEED must be a u64");
        let mut rng = Pcg32::seeded(seed);
        property(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = 0x9E37_79B9_7F4A_7C15u64
            .wrapping_mul(case + 1)
            .wrapping_add(0xDEAD_BEEF);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Pcg32::seeded(seed);
            property(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} \
                 (replay with PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Generator helpers shared by property tests.
pub mod gen {
    use super::Pcg32;

    /// Vector of length in [lo, hi) with elements from `f`.
    pub fn vec_of<T>(
        rng: &mut Pcg32,
        lo: usize,
        hi: usize,
        mut f: impl FnMut(&mut Pcg32) -> T,
    ) -> Vec<T> {
        let len = rng.range_usize(lo, hi);
        (0..len).map(|_| f(rng)).collect()
    }

    /// A plausible request length: mixture of short/medium/long.
    pub fn seq_len(rng: &mut Pcg32, max: usize) -> usize {
        let bucket = rng.below(3);
        let hi = match bucket {
            0 => max / 8,
            1 => max / 2,
            _ => max,
        }
        .max(2);
        rng.range_usize(1, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("u32 below bound", 50, |rng| {
            let b = 1 + rng.below(100);
            assert!(rng.below(b) < b);
        });
    }

    #[test]
    #[should_panic(expected = "replay with PROP_SEED=")]
    fn reports_failing_seed() {
        prop_check("always fails eventually", 20, |rng| {
            assert!(rng.next_f32() < 0.5, "drew a large value");
        });
    }

    #[test]
    fn gen_vec_respects_bounds() {
        prop_check("vec_of bounds", 50, |rng| {
            let v = gen::vec_of(rng, 2, 10, |r| r.next_u32());
            assert!((2..10).contains(&v.len()));
        });
    }
}
