//! Minimal TOML subset parser for the config system.
//!
//! Supports the subset the launcher configs use: `[table.subtable]`
//! headers, top-level `[[array-of-tables]]` headers (each occurrence
//! appends a fresh table — what `serve.toml`'s repeated `[[model]]`
//! entries use), `key = value` with strings, integers, floats, booleans
//! and homogeneous inline arrays, plus `#` comments.  Values land in the
//! same [`Json`] tree the manifest uses, so the config layer has one
//! value type.

use super::json::Json;
use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, TomlError> {
    Err(TomlError { line, msg: msg.into() })
}

/// Parse TOML text into a nested `Json::Obj` tree.
pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix("[[") {
            let header = match header.strip_suffix("]]") {
                Some(h) => h.trim(),
                None => {
                    return err(
                        line_no,
                        "unterminated array-of-tables header",
                    )
                }
            };
            if header.is_empty() {
                return err(line_no, "empty array-of-tables header");
            }
            if header.contains('.') {
                return err(
                    line_no,
                    "nested array-of-tables not supported",
                );
            }
            // each [[name]] appends a fresh table; following keys land
            // in it (ensure_table descends into an array's last table)
            let entry = root
                .entry(header.to_string())
                .or_insert_with(|| Json::Arr(Vec::new()));
            match entry {
                Json::Arr(items) => items.push(Json::Obj(BTreeMap::new())),
                _ => {
                    return err(
                        line_no,
                        format!("'{header}' is not an array of tables"),
                    )
                }
            }
            current_path = vec![header.to_string()];
            continue;
        }
        if let Some(header) = line.strip_prefix('[') {
            let header = match header.strip_suffix(']') {
                Some(h) => h.trim(),
                None => return err(line_no, "unterminated table header"),
            };
            if header.is_empty() {
                return err(line_no, "empty table header");
            }
            current_path =
                header.split('.').map(|s| s.trim().to_string()).collect();
            ensure_table(&mut root, &current_path, line_no)?;
            continue;
        }
        let (key, val) = match line.split_once('=') {
            Some((k, v)) => (k.trim(), v.trim()),
            None => return err(line_no, "expected 'key = value'"),
        };
        if key.is_empty() {
            return err(line_no, "empty key");
        }
        let parsed = parse_value(val, line_no)?;
        let table = ensure_table(&mut root, &current_path, line_no)?;
        if table.insert(key.to_string(), parsed).is_some() {
            return err(line_no, format!("duplicate key '{key}'"));
        }
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
    line: usize,
) -> Result<&'a mut BTreeMap<String, Json>, TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        match entry {
            Json::Obj(m) => cur = m,
            // an array of tables: keys land in its latest element
            Json::Arr(items) => match items.last_mut() {
                Some(Json::Obj(m)) => cur = m,
                _ => {
                    return err(line, format!("'{part}' is not a table"))
                }
            },
            _ => return err(line, format!("'{part}' is not a table")),
        }
    }
    Ok(cur)
}

fn parse_value(text: &str, line: usize) -> Result<Json, TomlError> {
    let t = text.trim();
    if t.is_empty() {
        return err(line, "missing value");
    }
    if let Some(inner) = t.strip_prefix('"') {
        let inner = match inner.strip_suffix('"') {
            Some(s) => s,
            None => return err(line, "unterminated string"),
        };
        return Ok(Json::Str(inner.replace("\\n", "\n").replace("\\\"", "\"")));
    }
    if let Some(inner) = t.strip_prefix('[') {
        let inner = match inner.strip_suffix(']') {
            Some(s) => s,
            None => return err(line, "unterminated array"),
        };
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    match t {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    if let Ok(n) = t.parse::<f64>() {
        return Ok(Json::Num(n));
    }
    err(line, format!("cannot parse value '{t}'"))
}

/// Split on commas that are not inside nested brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let (mut depth, mut in_str, mut start) = (0usize, false, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_keys() {
        let v = parse("a = 1\nb = \"x\"\nc = true\nd = 2.5").unwrap();
        assert_eq!(v.get("a").as_f64(), Some(1.0));
        assert_eq!(v.get("b").as_str(), Some("x"));
        assert_eq!(v.get("c").as_bool(), Some(true));
        assert_eq!(v.get("d").as_f64(), Some(2.5));
    }

    #[test]
    fn parses_tables_and_nesting() {
        let v = parse("[model]\nd = 64\n[serving.batcher]\nmax = 8").unwrap();
        assert_eq!(v.get("model").get("d").as_usize(), Some(64));
        assert_eq!(
            v.get("serving").get("batcher").get("max").as_usize(),
            Some(8)
        );
    }

    #[test]
    fn parses_arrays() {
        let v = parse("ks = [8, 16, 32]\nnames = [\"a\", \"b\"]").unwrap();
        assert_eq!(v.get("ks").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("names").idx(1).as_str(), Some("b"));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let v = parse("# top\na = 1  # trailing\n\nb = \"has # inside\"")
            .unwrap();
        assert_eq!(v.get("a").as_usize(), Some(1));
        assert_eq!(v.get("b").as_str(), Some("has # inside"));
    }

    #[test]
    fn parses_array_of_tables() {
        let v = parse(
            "[serving]\nqueue = 8\n\
             [[model]]\nname = \"tiny\"\nseed = 1\n\
             [[model]]\nname = \"big\"\ncheckpoint = \"w.bin\"\n",
        )
        .unwrap();
        assert_eq!(v.get("serving").get("queue").as_usize(), Some(8));
        let models = v.get("model").as_arr().unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].get("name").as_str(), Some("tiny"));
        assert_eq!(models[0].get("seed").as_usize(), Some(1));
        assert_eq!(models[1].get("name").as_str(), Some("big"));
        assert_eq!(models[1].get("checkpoint").as_str(), Some("w.bin"));
    }

    #[test]
    fn array_of_tables_conflicts_detected() {
        assert!(parse("a = 1\n[[a]]\nb = 2").is_err());
        assert!(parse("[[a.b]]\nc = 1").is_err());
        assert!(parse("[[unterminated]\nc = 1").is_err());
        // a plain [a] header after [[a]] lands in the last element; a
        // scalar key conflicting with the array still errors
        assert!(parse("[[a]]\nx = 1\n[a]\nx = 2").is_err()); // dup key
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("bad value").is_err());
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("x = \"open").is_err());
    }

    #[test]
    fn table_conflict_detected() {
        assert!(parse("a = 1\n[a]\nb = 2").is_err());
    }
}
