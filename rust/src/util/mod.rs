//! From-scratch utility substrates (the offline build has no serde_json,
//! toml, clap, criterion, proptest or rand — see Cargo.toml).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod toml;
