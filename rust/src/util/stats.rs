//! Timing statistics for the benchmark harness (criterion is unavailable
//! offline, so `cargo bench` targets use this module with `harness = false`),
//! plus the machine-readable bench log (`BENCH_encoder.json`) that gives
//! future PRs a perf trajectory.

use std::time::{Duration, Instant};

use crate::util::json::{self, Json};

/// Summary statistics over a sample of durations (seconds).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from_secs(mut xs: Vec<f64>) -> Summary {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        // Bessel-corrected sample variance: divisor n−1 (a single sample
        // has zero spread, not half of it — the old `n.max(2)` divisor
        // biased every ±std in BENCH_encoder.json, for every n).
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n - 1).max(1) as f64;
        let pct = |p: f64| xs[(((n - 1) as f64) * p).round() as usize];
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: xs[n - 1],
        }
    }

    /// Render as "12.3ms ±0.4 (p50 12.1, p95 13.0)".
    pub fn human(&self) -> String {
        fn fmt(s: f64) -> String {
            if s >= 1.0 {
                format!("{s:.3}s")
            } else if s >= 1e-3 {
                format!("{:.2}ms", s * 1e3)
            } else {
                format!("{:.1}us", s * 1e6)
            }
        }
        format!(
            "{} ±{} (p50 {}, p95 {}, n={})",
            fmt(self.mean),
            fmt(self.std),
            fmt(self.p50),
            fmt(self.p95),
            self.n
        )
    }
}

/// Benchmark runner: warms up, then times `iters` calls of `f`.
///
/// `f` returns an opaque value that is black-boxed to stop the optimizer
/// from deleting the work.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::from_secs(samples)
}

/// Time-budgeted runner: runs until `budget` elapses (at least `min_iters`).
pub fn bench_for<T>(
    budget: Duration,
    min_iters: usize,
    mut f: impl FnMut() -> T,
) -> Summary {
    black_box(f()); // warmup
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < min_iters || start.elapsed() < budget {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    Summary::from_secs(samples)
}

/// Optimization barrier (stable-Rust clone of `std::hint::black_box`
/// semantics via volatile read).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Rank-based percentile of an ascending-sorted slice (0.0 if empty).
/// The one shared convention (`⌊len·q⌋`, clamped) — serving reports and
/// trace replays must agree on what "p99" means to be comparable.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    sorted
        .get(
            ((sorted.len() as f64 * q) as usize)
                .min(sorted.len().saturating_sub(1)),
        )
        .copied()
        .unwrap_or(0.0)
}

/// Build one machine-readable bench record from (key, value) pairs.
pub fn bench_record(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(
        pairs
            .iter()
            .map(|(k, v)| ((*k).to_string(), v.clone()))
            .collect(),
    )
}

/// Merge a named section of bench records into a JSON file (each bench
/// binary owns one top-level key, so fig2/table3 can share
/// `BENCH_encoder.json` without clobbering each other).  IO errors are
/// reported to stderr, never fatal — benches must not fail on a
/// read-only checkout.
pub fn emit_bench_json(path: &str, section: &str, records: Vec<Json>) {
    let mut root = match std::fs::read_to_string(path) {
        Ok(s) => match json::parse(&s) {
            Ok(Json::Obj(m)) => m,
            Ok(_) | Err(_) => {
                eprintln!(
                    "[bench] warning: {path} exists but is not a JSON \
                     object; starting a fresh log"
                );
                Default::default()
            }
        },
        Err(_) => Default::default(), // no existing log
    };
    root.insert(section.to_string(), Json::Arr(records));
    let body = Json::Obj(root).to_string();
    // write-then-rename so a killed bench never truncates the log
    let tmp = format!("{path}.tmp");
    let result = std::fs::write(&tmp, body)
        .and_then(|()| std::fs::rename(&tmp, path));
    match result {
        Ok(()) => println!("[bench] wrote {path} (section '{section}')"),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles_ordered() {
        let s = Summary::from_secs((1..=100).map(|i| i as f64 / 100.0).collect());
        assert_eq!(s.n, 100);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99);
        assert!(s.p99 <= s.max);
        assert!((s.mean - 0.505).abs() < 1e-9);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::from_secs(vec![0.25]);
        assert_eq!(s.p50, 0.25);
        assert_eq!(s.max, 0.25);
        // one sample has no spread (the old n.max(2) divisor reported
        // half the squared deviation instead of zero)
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn summary_std_is_bessel_corrected() {
        // hand-computed: xs = [1, 2, 3, 4]; mean 2.5;
        // Σ(x−mean)² = 2.25 + 0.25 + 0.25 + 2.25 = 5;
        // sample variance = 5 / (4−1) = 5/3; std = √(5/3) ≈ 1.290994…
        let s = Summary::from_secs(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!(
            (s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12,
            "std {} != sqrt(5/3)",
            s.std
        );
        // two samples: variance = Σ/1, not Σ/2 (the old divisor)
        let s2 = Summary::from_secs(vec![0.0, 2.0]);
        assert!((s2.std - 2.0f64.sqrt()).abs() < 1e-12, "std {}", s2.std);
    }

    #[test]
    fn bench_counts_iters() {
        let mut calls = 0usize;
        let s = bench(2, 5, || {
            calls += 1;
            calls
        });
        assert_eq!(s.n, 5);
        assert_eq!(calls, 7);
    }

    #[test]
    fn emit_bench_json_merges_sections() {
        let path = std::env::temp_dir().join("linformer_bench_emit_test.json");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let rec = |v: f64| {
            bench_record(&[("seq_len", Json::Num(128.0)), ("ns_per_token", Json::Num(v))])
        };
        emit_bench_json(&path, "fig2", vec![rec(1.0)]);
        emit_bench_json(&path, "table3", vec![rec(2.0), rec(3.0)]);
        // second write for the same section replaces it, keeps the other
        emit_bench_json(&path, "fig2", vec![rec(4.0)]);
        let parsed = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("fig2").as_arr().unwrap().len(), 1);
        assert_eq!(
            parsed.get("fig2").idx(0).get("ns_per_token").as_f64(),
            Some(4.0)
        );
        assert_eq!(parsed.get("table3").as_arr().unwrap().len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn human_formats_scales() {
        let s = Summary::from_secs(vec![2.0, 2.0]);
        assert!(s.human().contains("2.000s"));
        let ms = Summary::from_secs(vec![0.005, 0.005]);
        assert!(ms.human().contains("5.00ms"));
        let us = Summary::from_secs(vec![5e-5, 5e-5]);
        assert!(us.human().contains("50.0us"));
    }
}
