//! Deterministic PCG32 random number generator.
//!
//! Every stochastic component in the stack (corpus synthesis, MLM masking,
//! workload generation, property tests, spectrum probes) draws from this
//! single implementation so runs are reproducible from a seed recorded in
//! EXPERIMENTS.md.  PCG-XSH-RR 64/32 (O'Neill 2014).

/// PCG32 stream.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seeded stream; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = u64::from(x) * u64::from(bound);
            let low = m as u32;
            if low >= bound || low >= bound.wrapping_neg() % bound {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out {
            *v = self.normal() * sigma;
        }
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Sample an index from unnormalised weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut target = self.next_f32() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        let mut c = Pcg32::seeded(8);
        let xa: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();
        let xb: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        let xc: Vec<u32> = (0..16).map(|_| c.next_u32()).collect();
        assert_eq!(xa, xb);
        assert_ne!(xa, xc);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg32::seeded(1);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += f64::from(x);
        }
        let mean = sum / f64::from(n);
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_bounds_and_covers() {
        let mut rng = Pcg32::seeded(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = rng.below(7) as usize;
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = f64::from(rng.normal());
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / f64::from(n);
        let var = s2 / f64::from(n) - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = Pcg32::seeded(4);
        let mut counts = [0usize; 3];
        for _ in 0..9000 {
            counts[rng.weighted(&[1.0, 2.0, 6.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0], "{counts:?}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
