//! Workload traces: arrival-time + length + SLO streams for serving
//! evaluation.
//!
//! The paper benchmarks with "randomly generated data up to some sequence
//! length" (§5.3); production serving evaluations replay *traces*.  This
//! module synthesizes open-loop traces (Poisson or bursty MMPP-style
//! arrivals × mixed length distributions), optionally tags events with a
//! priority class + latency SLO, persists/reloads them as JSON, and
//! replays them against a [`Coordinator`] with correct open-loop timing
//! (late arrivals are not back-pressured by slow clients).  Replay
//! records a per-request outcome (served / deadline-missed / rejected /
//! shed / canceled / failed) and emits a machine-readable summary JSON so
//! benches can diff scheduling policies.

use std::time::{Duration, Instant};

use crate::coordinator::{
    Coordinator, Outcome, Priority, SubmitOptions, Task, Ticket,
};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::stats::percentile;
use std::collections::BTreeMap;

/// One trace entry: arrival offset, sequence length, scheduling class,
/// and the `(model, task)` the request addresses.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at_s: f64,
    pub len: usize,
    pub priority: Priority,
    /// Latency SLO in (trace-time) seconds; `None` = no deadline.
    pub slo_s: Option<f64>,
    /// Registered model name; `None` = the coordinator's default model
    /// (what every pre-registry trace replays as).
    pub model: Option<String>,
    /// Task kind (defaults to [`Task::MlmPredict`] in older traces).
    pub task: Task,
}

/// Length distribution families seen in long-document serving.
#[derive(Debug, Clone, Copy)]
pub enum LengthDist {
    /// Uniform in [1, max].
    Uniform { max: usize },
    /// Mostly short with a heavy tail of long documents:
    /// P(short) = 0.9 in [1, max/8], else [max/8, max].
    HeavyTail { max: usize },
    /// Bimodal chat/document mix.
    Bimodal { short: usize, long: usize },
}

impl LengthDist {
    fn sample(&self, rng: &mut Pcg32) -> usize {
        match *self {
            LengthDist::Uniform { max } => 1 + rng.below(max as u32) as usize,
            LengthDist::HeavyTail { max } => {
                if rng.chance(0.9) {
                    1 + rng.below((max / 8).max(1) as u32) as usize
                } else {
                    max / 8 + rng.below((max - max / 8).max(1) as u32) as usize
                }
            }
            LengthDist::Bimodal { short, long } => {
                if rng.chance(0.7) {
                    1 + rng.below(short as u32) as usize
                } else {
                    long / 2 + rng.below((long / 2).max(1) as u32) as usize
                }
            }
        }
    }
}

/// Synthesize an open-loop Poisson trace at `rate_rps` for `n` events.
/// Events default to interactive with no SLO (see [`assign_slos`]).
pub fn poisson_trace(
    n: usize,
    rate_rps: f64,
    dist: LengthDist,
    seed: u64,
) -> Vec<TraceEvent> {
    let mut rng = Pcg32::seeded(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // exponential inter-arrival
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / rate_rps;
            TraceEvent {
                at_s: t,
                len: dist.sample(&mut rng),
                priority: Priority::Interactive,
                slo_s: None,
                model: None,
                task: Task::MlmPredict,
            }
        })
        .collect()
}

/// Bursty trace: alternating high/low-rate phases (MMPP-2).
pub fn bursty_trace(
    n: usize,
    base_rps: f64,
    burst_rps: f64,
    phase_s: f64,
    dist: LengthDist,
    seed: u64,
) -> Vec<TraceEvent> {
    let mut rng = Pcg32::seeded(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let in_burst = ((t / phase_s) as u64) % 2 == 1;
            let rate = if in_burst { burst_rps } else { base_rps };
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / rate;
            TraceEvent {
                at_s: t,
                len: dist.sample(&mut rng),
                priority: Priority::Interactive,
                slo_s: None,
                model: None,
                task: Task::MlmPredict,
            }
        })
        .collect()
}

/// Round-robin a trace's events across `(model, task)` pairs — the
/// standard way to turn a single-tenant trace into a multi-tenant
/// workload for the registry scheduler.
pub fn assign_tenants(
    trace: &mut [TraceEvent],
    models: &[String],
    tasks: &[Task],
    seed: u64,
) {
    let mut rng = Pcg32::seeded(seed);
    for ev in trace.iter_mut() {
        if !models.is_empty() {
            let i = rng.below(models.len() as u32) as usize;
            ev.model = Some(models[i].clone());
        }
        if !tasks.is_empty() {
            ev.task = tasks[rng.below(tasks.len() as u32) as usize];
        }
    }
}

/// Tag a fraction of events as interactive-with-SLO; the rest become
/// deadline-less batch traffic.  This is the standard mixed-class
/// workload the scheduler benches and overload tests replay.
pub fn assign_slos(
    trace: &mut [TraceEvent],
    interactive_frac: f64,
    slo_s: f64,
    seed: u64,
) {
    let mut rng = Pcg32::seeded(seed);
    for ev in trace.iter_mut() {
        if rng.chance(interactive_frac as f32) {
            ev.priority = Priority::Interactive;
            ev.slo_s = Some(slo_s);
        } else {
            ev.priority = Priority::Batch;
            ev.slo_s = None;
        }
    }
}

/// Serialize a trace to JSON (replayable across runs/machines).
/// `model`/`task` ride along when non-default, so pre-registry tooling
/// keeps parsing the common case unchanged.
pub fn to_json(trace: &[TraceEvent]) -> String {
    let arr: Vec<Json> = trace
        .iter()
        .map(|e| {
            let mut m = BTreeMap::new();
            m.insert("at_s".to_string(), Json::Num(e.at_s));
            m.insert("len".to_string(), Json::Num(e.len as f64));
            m.insert(
                "priority".to_string(),
                Json::Str(e.priority.name().to_string()),
            );
            if let Some(slo) = e.slo_s {
                m.insert("slo_s".to_string(), Json::Num(slo));
            }
            if let Some(model) = &e.model {
                m.insert("model".to_string(), Json::Str(model.clone()));
            }
            if e.task != Task::MlmPredict {
                m.insert(
                    "task".to_string(),
                    Json::Str(e.task.name().to_string()),
                );
                if let Task::Classify { head } = e.task {
                    m.insert("head".to_string(), Json::Num(head as f64));
                }
            }
            Json::Obj(m)
        })
        .collect();
    Json::Arr(arr).to_string()
}

/// Parse a trace from JSON.  `priority`/`slo_s`/`model`/`task` are all
/// optional (older traces replay as interactive, deadline-less,
/// default-model MLM prediction).
pub fn from_json(text: &str) -> Result<Vec<TraceEvent>, String> {
    let v = crate::util::json::parse(text).map_err(|e| e.to_string())?;
    let arr = v.as_arr().ok_or("trace must be a JSON array")?;
    arr.iter()
        .map(|e| {
            let priority = match e.get("priority").as_str() {
                Some("batch") => Priority::Batch,
                Some("interactive") | None => Priority::Interactive,
                Some(o) => return Err(format!("unknown priority '{o}'")),
            };
            let slo_s = match e.get("slo_s") {
                Json::Null => None,
                v => Some(
                    v.as_f64().ok_or("slo_s must be a number")?,
                ),
            };
            let model = match e.get("model") {
                Json::Null => None,
                v => Some(
                    v.as_str()
                        .ok_or("model must be a string")?
                        .to_string(),
                ),
            };
            let task = match e.get("task").as_str() {
                None => Task::MlmPredict,
                Some(name) => {
                    let mut task = Task::from_name(name).ok_or_else(
                        || format!("unknown task '{name}'"),
                    )?;
                    if let Task::Classify { head } = &mut task {
                        *head = e.get("head").as_usize().unwrap_or(0);
                    }
                    task
                }
            };
            Ok(TraceEvent {
                at_s: e.get("at_s").as_f64().ok_or("missing at_s")?,
                len: e.get("len").as_usize().ok_or("missing len")?,
                priority,
                slo_s,
                model,
                task,
            })
        })
        .collect()
}

/// Per-request replay outcome (trace order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplayOutcome {
    /// Served within its SLO (or has none).
    Served,
    /// Served, but past its deadline.
    DeadlineMissed,
    /// Refused at submit (backpressure or admission control).
    Rejected,
    /// Expired in queue; dropped without being computed.
    Shed,
    /// Ticket dropped before dispatch.
    Canceled,
    /// Runner error or lost response.
    Failed,
}

impl ReplayOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            ReplayOutcome::Served => "served",
            ReplayOutcome::DeadlineMissed => "deadline_missed",
            ReplayOutcome::Rejected => "rejected",
            ReplayOutcome::Shed => "shed",
            ReplayOutcome::Canceled => "canceled",
            ReplayOutcome::Failed => "failed",
        }
    }
}

/// Replay outcome summary.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub sent: usize,
    /// Responses carrying predictions (served in or out of SLO).
    pub completed: usize,
    /// Everything else: submit rejections, shed, canceled, failed.
    pub rejected: usize,
    /// Served past deadline (subset of `completed`).
    pub deadline_missed: usize,
    /// Expired in queue, never computed.
    pub shed: usize,
    pub canceled: usize,
    pub wall_s: f64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
    /// p99 latency over served *interactive* requests (the SLO class).
    pub interactive_p99_s: f64,
    /// Fraction of events submitted within 1ms of their trace time
    /// (open-loop fidelity).
    pub on_time_frac: f64,
    /// Per-request outcome, in trace order.
    pub outcomes: Vec<ReplayOutcome>,
}

impl ReplayReport {
    pub fn count(&self, o: ReplayOutcome) -> usize {
        self.outcomes.iter().filter(|&&x| x == o).count()
    }

    /// Machine-readable summary for policy diffs (benches dump this).
    pub fn summary_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("sent".into(), Json::Num(self.sent as f64));
        for o in [
            ReplayOutcome::Served,
            ReplayOutcome::DeadlineMissed,
            ReplayOutcome::Rejected,
            ReplayOutcome::Shed,
            ReplayOutcome::Canceled,
            ReplayOutcome::Failed,
        ] {
            m.insert(o.name().into(), Json::Num(self.count(o) as f64));
        }
        m.insert("wall_s".into(), Json::Num(self.wall_s));
        m.insert(
            "mean_latency_s".into(),
            Json::Num(self.mean_latency_s),
        );
        m.insert("p99_latency_s".into(), Json::Num(self.p99_latency_s));
        m.insert(
            "interactive_p99_s".into(),
            Json::Num(self.interactive_p99_s),
        );
        m.insert("on_time_frac".into(), Json::Num(self.on_time_frac));
        Json::Obj(m)
    }
}

/// Replay a trace open-loop (arrivals follow trace time, optionally
/// time-scaled; SLOs scale with it so deadlines stay meaningful).
/// Responses are collected after the send loop, so slow requests never
/// delay later arrivals.
pub fn replay(
    coordinator: &Coordinator,
    trace: &[TraceEvent],
    vocab: usize,
    time_scale: f64,
) -> ReplayReport {
    let t0 = Instant::now();
    let mut tickets: Vec<(usize, Ticket)> = Vec::with_capacity(trace.len());
    let mut outcomes = vec![ReplayOutcome::Rejected; trace.len()];
    let mut on_time = 0usize;
    let mut rng = Pcg32::seeded(99);
    for (i, ev) in trace.iter().enumerate() {
        let due = ev.at_s * time_scale;
        let now = t0.elapsed().as_secs_f64();
        if due > now {
            std::thread::sleep(Duration::from_secs_f64(due - now));
        }
        if (t0.elapsed().as_secs_f64() - due).abs() < 1e-3 {
            on_time += 1;
        }
        let tokens: Vec<u32> = (0..ev.len.max(1))
            .map(|_| rng.below(vocab as u32))
            .collect();
        let opts = SubmitOptions {
            priority: ev.priority,
            slo: ev
                .slo_s
                .map(|s| Duration::from_secs_f64(s * time_scale)),
            model: ev.model.clone(),
            task: ev.task,
        };
        match coordinator.submit_with(tokens, opts) {
            Ok(t) => tickets.push((i, t)),
            Err(_) => outcomes[i] = ReplayOutcome::Rejected,
        }
    }
    let mut latencies = Vec::with_capacity(tickets.len());
    let mut interactive_lat = Vec::new();
    for (i, t) in tickets {
        let ev = &trace[i];
        outcomes[i] = match t.wait_timeout(Duration::from_secs(120)) {
            Ok(r) => match r.outcome {
                Outcome::Served => {
                    latencies.push(r.latency_s);
                    if ev.priority == Priority::Interactive {
                        interactive_lat.push(r.latency_s);
                    }
                    let late = ev
                        .slo_s
                        .is_some_and(|s| r.latency_s > s * time_scale);
                    if late {
                        ReplayOutcome::DeadlineMissed
                    } else {
                        ReplayOutcome::Served
                    }
                }
                Outcome::Rejected => ReplayOutcome::Rejected,
                Outcome::Shed => ReplayOutcome::Shed,
                Outcome::Canceled => ReplayOutcome::Canceled,
                Outcome::Failed => ReplayOutcome::Failed,
            },
            Err(_) => ReplayOutcome::Failed,
        };
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    interactive_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<f64>() / latencies.len() as f64
    };
    let completed = outcomes
        .iter()
        .filter(|&&o| {
            o == ReplayOutcome::Served || o == ReplayOutcome::DeadlineMissed
        })
        .count();
    ReplayReport {
        sent: trace.len(),
        completed,
        rejected: trace.len() - completed,
        deadline_missed: outcomes
            .iter()
            .filter(|&&o| o == ReplayOutcome::DeadlineMissed)
            .count(),
        shed: outcomes
            .iter()
            .filter(|&&o| o == ReplayOutcome::Shed)
            .count(),
        canceled: outcomes
            .iter()
            .filter(|&&o| o == ReplayOutcome::Canceled)
            .count(),
        wall_s: wall,
        mean_latency_s: mean,
        p99_latency_s: percentile(&latencies, 0.99),
        interactive_p99_s: percentile(&interactive_lat, 0.99),
        on_time_frac: on_time as f64 / trace.len().max(1) as f64,
        outcomes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_is_sorted_and_rate_close() {
        let t = poisson_trace(2000, 100.0, LengthDist::Uniform { max: 64 }, 1);
        assert!(t.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        let span = t.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
    }

    #[test]
    fn heavy_tail_is_mostly_short() {
        let mut rng = Pcg32::seeded(2);
        let d = LengthDist::HeavyTail { max: 1024 };
        let lens: Vec<usize> = (0..2000).map(|_| d.sample(&mut rng)).collect();
        let short = lens.iter().filter(|&&l| l <= 128).count();
        assert!(short > 1600, "short {short}");
        assert!(lens.iter().any(|&l| l > 512), "no tail");
        assert!(lens.iter().all(|&l| (1..=1024).contains(&l)));
    }

    #[test]
    fn bursty_trace_has_rate_variation() {
        let t = bursty_trace(
            4000,
            50.0,
            500.0,
            0.5,
            LengthDist::Uniform { max: 32 },
            3,
        );
        // count arrivals per phase window; variance must exceed Poisson's
        let span = t.last().unwrap().at_s;
        let windows = (span / 0.5).ceil() as usize;
        let mut counts = vec![0f64; windows + 1];
        for e in &t {
            counts[(e.at_s / 0.5) as usize] += 1.0;
        }
        let mean = counts.iter().sum::<f64>() / counts.len() as f64;
        let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
            / counts.len() as f64;
        assert!(var > 2.0 * mean, "var {var} mean {mean}");
    }

    #[test]
    fn assign_slos_splits_classes() {
        let mut t =
            poisson_trace(500, 100.0, LengthDist::Uniform { max: 32 }, 8);
        assign_slos(&mut t, 0.7, 0.05, 9);
        let interactive = t
            .iter()
            .filter(|e| e.priority == Priority::Interactive)
            .count();
        assert!(
            (250..450).contains(&interactive),
            "interactive {interactive}"
        );
        for e in &t {
            match e.priority {
                Priority::Interactive => assert_eq!(e.slo_s, Some(0.05)),
                Priority::Batch => assert_eq!(e.slo_s, None),
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut t = poisson_trace(
            50,
            10.0,
            LengthDist::Bimodal { short: 32, long: 256 },
            4,
        );
        assign_slos(&mut t, 0.5, 0.1, 5);
        assign_tenants(
            &mut t,
            &["small".to_string(), "big".to_string()],
            &[
                Task::MlmPredict,
                Task::Encode,
                Task::Classify { head: 0 },
            ],
            6,
        );
        let s = to_json(&t);
        let back = from_json(&s).unwrap();
        assert_eq!(back.len(), t.len());
        for (a, b) in t.iter().zip(&back) {
            assert_eq!(a.len, b.len);
            assert!((a.at_s - b.at_s).abs() < 1e-9);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.slo_s, b.slo_s);
            assert_eq!(a.model, b.model);
            assert_eq!(a.task, b.task);
        }
        // the tenant mix actually varied
        assert!(t.iter().any(|e| e.model.as_deref() == Some("small")));
        assert!(t.iter().any(|e| e.model.as_deref() == Some("big")));
        assert!(t.iter().any(|e| e.task == Task::Encode));
    }

    #[test]
    fn from_json_rejects_garbage_and_defaults_optionals() {
        assert!(from_json("{}").is_err());
        assert!(from_json("[{\"at_s\": 1}]").is_err());
        assert!(from_json("not json").is_err());
        assert!(
            from_json("[{\"at_s\": 1, \"len\": 2, \"priority\": \"vip\"}]")
                .is_err()
        );
        // a malformed SLO must not silently replay deadline-less
        assert!(
            from_json("[{\"at_s\": 1, \"len\": 2, \"slo_s\": \"0.05\"}]")
                .is_err()
        );
        // an unknown task name must not silently replay as MLM
        assert!(
            from_json("[{\"at_s\": 1, \"len\": 2, \"task\": \"dream\"}]")
                .is_err()
        );
        // legacy traces (no priority/slo/model/task) parse as
        // interactive, no-SLO, default-model MLM prediction
        let t = from_json("[{\"at_s\": 1.5, \"len\": 2}]").unwrap();
        assert_eq!(t[0].priority, Priority::Interactive);
        assert_eq!(t[0].slo_s, None);
        assert_eq!(t[0].model, None);
        assert_eq!(t[0].task, Task::MlmPredict);
        // classify round-trips its head index
        let t = from_json(
            "[{\"at_s\": 1, \"len\": 2, \"task\": \"classify\", \
              \"head\": 0, \"model\": \"big\"}]",
        )
        .unwrap();
        assert_eq!(t[0].task, Task::Classify { head: 0 });
        assert_eq!(t[0].model.as_deref(), Some("big"));
    }

    #[test]
    fn replay_against_mock_coordinator() {
        use crate::coordinator::{
            BatcherConfig, BucketSpec, Coordinator, MockRunner, RunnerFactory,
        };
        let factory: RunnerFactory = Box::new(|| {
            Ok(Box::new(MockRunner {
                capacity: 8,
                len: 64,
                delay: Duration::from_millis(1),
                fail: false,
            }) as Box<dyn crate::coordinator::BatchRunner>)
        });
        let coord = Coordinator::start(
            vec![(BucketSpec { max_len: 64, batch: 8 }, factory)],
            BatcherConfig::default(),
        );
        let trace =
            poisson_trace(40, 2000.0, LengthDist::Uniform { max: 64 }, 5);
        let report = replay(&coord, &trace, 128, 1.0);
        assert_eq!(report.sent, 40);
        assert_eq!(report.completed + report.rejected, 40);
        assert!(report.completed > 30);
        assert_eq!(report.outcomes.len(), 40);
        // machine-readable summary accounts for every event
        let j = report.summary_json();
        let total: usize = [
            "served",
            "deadline_missed",
            "rejected",
            "shed",
            "canceled",
            "failed",
        ]
        .iter()
        .map(|k| j.get(k).as_usize().unwrap())
        .sum();
        assert_eq!(total, 40);
        coord.shutdown();
    }
}
