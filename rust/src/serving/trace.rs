//! Workload traces: arrival-time + length streams for serving evaluation.
//!
//! The paper benchmarks with "randomly generated data up to some sequence
//! length" (§5.3); production serving evaluations replay *traces*.  This
//! module synthesizes open-loop traces (Poisson or bursty MMPP-style
//! arrivals × mixed length distributions), can persist/reload them as
//! JSON, and replays them against a [`Coordinator`] with correct open-loop
//! timing (late arrivals are not back-pressured by slow clients).

use std::time::{Duration, Instant};

use crate::coordinator::Coordinator;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;

/// One trace entry: arrival offset + sequence length.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at_s: f64,
    pub len: usize,
}

/// Length distribution families seen in long-document serving.
#[derive(Debug, Clone, Copy)]
pub enum LengthDist {
    /// Uniform in [1, max].
    Uniform { max: usize },
    /// Mostly short with a heavy tail of long documents:
    /// P(short) = 0.9 in [1, max/8], else [max/8, max].
    HeavyTail { max: usize },
    /// Bimodal chat/document mix.
    Bimodal { short: usize, long: usize },
}

impl LengthDist {
    fn sample(&self, rng: &mut Pcg32) -> usize {
        match *self {
            LengthDist::Uniform { max } => 1 + rng.below(max as u32) as usize,
            LengthDist::HeavyTail { max } => {
                if rng.chance(0.9) {
                    1 + rng.below((max / 8).max(1) as u32) as usize
                } else {
                    max / 8 + rng.below((max - max / 8).max(1) as u32) as usize
                }
            }
            LengthDist::Bimodal { short, long } => {
                if rng.chance(0.7) {
                    1 + rng.below(short as u32) as usize
                } else {
                    long / 2 + rng.below((long / 2).max(1) as u32) as usize
                }
            }
        }
    }
}

/// Synthesize an open-loop Poisson trace at `rate_rps` for `n` events.
pub fn poisson_trace(
    n: usize,
    rate_rps: f64,
    dist: LengthDist,
    seed: u64,
) -> Vec<TraceEvent> {
    let mut rng = Pcg32::seeded(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // exponential inter-arrival
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / rate_rps;
            TraceEvent { at_s: t, len: dist.sample(&mut rng) }
        })
        .collect()
}

/// Bursty trace: alternating high/low-rate phases (MMPP-2).
pub fn bursty_trace(
    n: usize,
    base_rps: f64,
    burst_rps: f64,
    phase_s: f64,
    dist: LengthDist,
    seed: u64,
) -> Vec<TraceEvent> {
    let mut rng = Pcg32::seeded(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let in_burst = ((t / phase_s) as u64) % 2 == 1;
            let rate = if in_burst { burst_rps } else { base_rps };
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / rate;
            TraceEvent { at_s: t, len: dist.sample(&mut rng) }
        })
        .collect()
}

/// Serialize a trace to JSON (replayable across runs/machines).
pub fn to_json(trace: &[TraceEvent]) -> String {
    let arr: Vec<Json> = trace
        .iter()
        .map(|e| {
            let mut m = BTreeMap::new();
            m.insert("at_s".to_string(), Json::Num(e.at_s));
            m.insert("len".to_string(), Json::Num(e.len as f64));
            Json::Obj(m)
        })
        .collect();
    Json::Arr(arr).to_string()
}

/// Parse a trace from JSON.
pub fn from_json(text: &str) -> Result<Vec<TraceEvent>, String> {
    let v = crate::util::json::parse(text).map_err(|e| e.to_string())?;
    let arr = v.as_arr().ok_or("trace must be a JSON array")?;
    arr.iter()
        .map(|e| {
            Ok(TraceEvent {
                at_s: e.get("at_s").as_f64().ok_or("missing at_s")?,
                len: e.get("len").as_usize().ok_or("missing len")?,
            })
        })
        .collect()
}

/// Replay outcome.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub sent: usize,
    pub completed: usize,
    pub rejected: usize,
    pub wall_s: f64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
    /// Fraction of events submitted within 1ms of their trace time
    /// (open-loop fidelity).
    pub on_time_frac: f64,
}

/// Replay a trace open-loop (arrivals follow trace time, optionally
/// time-scaled; responses are collected on a separate thread so slow
/// requests never delay later arrivals).
pub fn replay(
    coordinator: &Coordinator,
    trace: &[TraceEvent],
    vocab: usize,
    time_scale: f64,
) -> ReplayReport {
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(trace.len());
    let mut rejected = 0usize;
    let mut on_time = 0usize;
    let mut rng = Pcg32::seeded(99);
    for ev in trace {
        let due = ev.at_s * time_scale;
        let now = t0.elapsed().as_secs_f64();
        if due > now {
            std::thread::sleep(Duration::from_secs_f64(due - now));
        }
        if (t0.elapsed().as_secs_f64() - due).abs() < 1e-3 {
            on_time += 1;
        }
        let tokens: Vec<u32> = (0..ev.len.max(1))
            .map(|_| rng.below(vocab as u32))
            .collect();
        match coordinator.submit(tokens) {
            Ok(t) => tickets.push(t),
            Err(_) => rejected += 1,
        }
    }
    let mut latencies = Vec::with_capacity(tickets.len());
    let mut completed = 0usize;
    for t in tickets {
        match t.wait_timeout(Duration::from_secs(120)) {
            Ok(r) if !r.predictions.is_empty() => {
                completed += 1;
                latencies.push(r.latency_s);
            }
            _ => rejected += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<f64>() / latencies.len() as f64
    };
    let p99 = latencies
        .get(((latencies.len() as f64 * 0.99) as usize)
            .min(latencies.len().saturating_sub(1)))
        .copied()
        .unwrap_or(0.0);
    ReplayReport {
        sent: trace.len(),
        completed,
        rejected,
        wall_s: wall,
        mean_latency_s: mean,
        p99_latency_s: p99,
        on_time_frac: on_time as f64 / trace.len().max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_is_sorted_and_rate_close() {
        let t = poisson_trace(2000, 100.0, LengthDist::Uniform { max: 64 }, 1);
        assert!(t.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        let span = t.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
    }

    #[test]
    fn heavy_tail_is_mostly_short() {
        let mut rng = Pcg32::seeded(2);
        let d = LengthDist::HeavyTail { max: 1024 };
        let lens: Vec<usize> = (0..2000).map(|_| d.sample(&mut rng)).collect();
        let short = lens.iter().filter(|&&l| l <= 128).count();
        assert!(short > 1600, "short {short}");
        assert!(lens.iter().any(|&l| l > 512), "no tail");
        assert!(lens.iter().all(|&l| (1..=1024).contains(&l)));
    }

    #[test]
    fn bursty_trace_has_rate_variation() {
        let t = bursty_trace(
            4000,
            50.0,
            500.0,
            0.5,
            LengthDist::Uniform { max: 32 },
            3,
        );
        // count arrivals per phase window; variance must exceed Poisson's
        let span = t.last().unwrap().at_s;
        let windows = (span / 0.5).ceil() as usize;
        let mut counts = vec![0f64; windows + 1];
        for e in &t {
            counts[(e.at_s / 0.5) as usize] += 1.0;
        }
        let mean = counts.iter().sum::<f64>() / counts.len() as f64;
        let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
            / counts.len() as f64;
        assert!(var > 2.0 * mean, "var {var} mean {mean}");
    }

    #[test]
    fn json_roundtrip() {
        let t = poisson_trace(50, 10.0, LengthDist::Bimodal { short: 32, long: 256 }, 4);
        let s = to_json(&t);
        let back = from_json(&s).unwrap();
        assert_eq!(back.len(), t.len());
        for (a, b) in t.iter().zip(&back) {
            assert_eq!(a.len, b.len);
            assert!((a.at_s - b.at_s).abs() < 1e-9);
        }
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(from_json("{}").is_err());
        assert!(from_json("[{\"at_s\": 1}]").is_err());
        assert!(from_json("not json").is_err());
    }

    #[test]
    fn replay_against_mock_coordinator() {
        use crate::coordinator::{
            BatcherConfig, BucketSpec, Coordinator, MockRunner, RunnerFactory,
        };
        let factory: RunnerFactory = Box::new(|| {
            Ok(Box::new(MockRunner {
                capacity: 8,
                len: 64,
                delay: Duration::from_millis(1),
                fail: false,
            }) as Box<dyn crate::coordinator::BatchRunner>)
        });
        let coord = Coordinator::start(
            vec![(BucketSpec { max_len: 64, batch: 8 }, factory)],
            BatcherConfig::default(),
        );
        let trace =
            poisson_trace(40, 2000.0, LengthDist::Uniform { max: 64 }, 5);
        let report = replay(&coord, &trace, 128, 1.0);
        assert_eq!(report.sent, 40);
        assert_eq!(report.completed + report.rejected, 40);
        assert!(report.completed > 30);
        coord.shutdown();
    }
}
