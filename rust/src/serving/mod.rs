//! Serving assembly: wire a multi-tenant [`ModelRegistry`] (pure-Rust
//! reference encoder) or manifest artifacts (PJRT) into a running
//! [`Coordinator`], plus a synthetic client-load generator used by the
//! examples and benches.

use std::sync::Arc;
use std::time::{Duration, Instant};

pub mod config;
pub mod trace;

pub use config::LauncherConfig;

#[cfg(feature = "pjrt")]
use crate::coordinator::{
    LocalBatchRunner, LocalRunnerFactory, PinnedRunner, XlaRunner,
};
use crate::coordinator::{
    BatchRunner, BatcherConfig, BucketSpec, Coordinator, CostModel,
    ModelRegistry, Outcome, ReferenceRunner, RunnerFactory, SubmitOptions,
    Task,
};
use crate::data::{Corpus, CorpusConfig};
use crate::model::{ModelConfig, Params};
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, Manifest};
#[cfg(feature = "pjrt")]
use crate::training::TrainError;
use crate::util::rng::Pcg32;

/// Build a multi-tenant coordinator over a shared [`ModelRegistry`]:
/// every bucket's runner dispatches any registered `(model, task)`
/// through the pure-Rust batched reference encoder — no artifacts, no
/// PJRT.  `buckets` lists `(max_len, batch_capacity)` pairs; the
/// registry's first-registered model is the default target.  All bucket
/// runners draw their compute from the process-wide pool, so
/// concurrently-busy buckets never oversubscribe the thread budget, and
/// [`ModelRegistry::reload`] hot-swaps any model's weights under live
/// traffic.
pub fn build_registry_coordinator(
    registry: Arc<ModelRegistry>,
    buckets: &[(usize, usize)],
    config: BatcherConfig,
) -> Coordinator {
    assert!(!buckets.is_empty(), "at least one bucket required");
    let default_model = registry
        .default_model()
        .expect("registry must hold at least one model");
    let max_model_len = registry.max_len();
    let mut sorted = buckets.to_vec();
    sorted.sort_by_key(|&(len, _)| len);
    let mut specs: Vec<(BucketSpec, RunnerFactory)> = Vec::new();
    for (len, cap) in sorted {
        // validate here, on the calling thread: failing inside a runner
        // factory would only fire on the scheduler thread, leaving
        // clients to time out instead of failing fast
        assert!(
            len <= max_model_len,
            "bucket length {len} exceeds every model's max_len \
             ({max_model_len})"
        );
        assert!(cap > 0, "bucket capacity must be positive");
        let registry = Arc::clone(&registry);
        let factory: RunnerFactory = Box::new(move || {
            Ok(Box::new(ReferenceRunner::new(registry, len, cap))
                as Box<dyn BatchRunner>)
        });
        specs.push((BucketSpec { max_len: len, batch: cap }, factory));
    }
    Coordinator::start_with(specs, config, Some(registry), &default_model)
}

/// Single-model convenience over [`build_registry_coordinator`]: wraps
/// `(cfg, params)` into a one-entry registry named `"default"`.  This is
/// the pre-registry API, preserved verbatim — and the serving path on
/// machines without the `pjrt` feature.
pub fn build_reference_coordinator(
    cfg: &ModelConfig,
    params: &Arc<Params>,
    buckets: &[(usize, usize)],
    config: BatcherConfig,
) -> Coordinator {
    for &(len, _) in buckets {
        assert!(
            len <= cfg.max_len,
            "bucket length {len} exceeds model max_len {}",
            cfg.max_len
        );
    }
    let registry = Arc::new(ModelRegistry::new());
    registry
        .register("default", cfg.clone(), Arc::clone(params))
        .unwrap_or_else(|e| panic!("register default model: {e}"));
    build_registry_coordinator(registry, buckets, config)
}

/// Build a coordinator from manifest models (ascending max_len buckets).
///
/// Each named model becomes one bucket backed by its `mlm_logits` program
/// and `init.bin` (or checkpoint) parameters.  PJRT handles are `!Send`,
/// so each bucket's [`XlaRunner`] is built inside a [`PinnedRunner`]: a
/// dedicated thread owns the engine + executable and the scheduler's
/// pool tasks forward batches to it.  All buckets are *launched* here,
/// before the coordinator starts, so their engine/compile work runs
/// concurrently (startup is the slowest compile, not the sum).
///
/// A compiled executable is one `(model, program)` pair, so this path
/// serves `Task::MlmPredict` against the bucket-owning model only —
/// multi-task dispatch needs the reference path (or more compiled
/// programs per entry; see ROADMAP).  Requests default to the first
/// named model.
#[cfg(feature = "pjrt")]
pub fn build_coordinator(
    manifest: &Manifest,
    model_names: &[&str],
    config: BatcherConfig,
) -> Result<Coordinator, TrainError> {
    let mut entries: Vec<&crate::runtime::ModelEntry> = model_names
        .iter()
        .map(|n| manifest.model(n))
        .collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.config.max_len);
    let default_model = model_names.first().copied().unwrap_or("default");
    let mut buckets: Vec<(BucketSpec, RunnerFactory)> = Vec::new();
    for entry in entries {
        let spec = BucketSpec {
            max_len: entry.config.max_len,
            batch: entry.batch,
        };
        let info = entry.program("mlm_logits")?.clone();
        let params = entry.load_init()?;
        let batch = entry.batch;
        let (len, vocab) = (entry.config.max_len, entry.config.vocab_size);
        let local: LocalRunnerFactory = Box::new(move || {
            let engine = Engine::cpu().map_err(|e| e.to_string())?;
            let exe = engine
                .load_program(&info)
                .map_err(|e| e.to_string())?;
            Ok(Box::new(XlaRunner::new(exe, params, batch, len, vocab))
                as Box<dyn LocalBatchRunner>)
        });
        // launch now (compiles start concurrently); the coordinator's
        // factory only waits for readiness
        let pending = PinnedRunner::launch(local)
            .map_err(crate::training::TrainError::Serving)?;
        let factory: RunnerFactory = Box::new(move || {
            Ok(Box::new(pending.wait()?) as Box<dyn BatchRunner>)
        });
        buckets.push((spec, factory));
    }
    Ok(Coordinator::start_with(buckets, config, None, default_model))
}

/// Default serving batcher config tuned for the Linformer cost model:
/// EDF scheduling, admission control and expiry shedding on.
pub fn default_config(k: usize) -> BatcherConfig {
    BatcherConfig {
        max_delay: Duration::from_millis(10),
        queue_capacity: 512,
        merge_up: true,
        cost_model: CostModel::Linear { k },
        ..BatcherConfig::default()
    }
}

/// Result of a synthetic load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub sent: usize,
    pub completed: usize,
    pub rejected: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub mean_latency_s: f64,
    pub p95_latency_s: f64,
}

/// Drive `total` requests with mixed lengths through the coordinator from
/// `clients` threads; lengths are sampled in [1, max_len].  Targets the
/// default model's default task — see [`run_load_mix`] for multi-tenant
/// load.
pub fn run_load(
    coordinator: &Coordinator,
    vocab: usize,
    total: usize,
    clients: usize,
    seed: u64,
) -> LoadReport {
    run_load_mix(coordinator, vocab, total, clients, seed, &[], &[])
}

/// Multi-tenant load generator: each request picks a uniform-random
/// `(model, task)` from the given mixes (empty mix = the coordinator's
/// default).  Lengths respect both the bucket ceiling and the chosen
/// model's `max_len`.  "Completed" means `Outcome::Served` — the right
/// signal for float-valued tasks whose `predictions` view is empty.
pub fn run_load_mix(
    coordinator: &Coordinator,
    vocab: usize,
    total: usize,
    clients: usize,
    seed: u64,
    models: &[String],
    tasks: &[Task],
) -> LoadReport {
    let corpus = Arc::new(Corpus::new(
        CorpusConfig {
            vocab_words: vocab - crate::data::tokenizer::NUM_SPECIAL as usize,
            ..CorpusConfig::default()
        },
        seed,
    ));
    let max_len = coordinator.max_len();
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(total);
    let (mut completed, mut rejected) = (0usize, 0usize);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let corpus = Arc::clone(&corpus);
            let share =
                total / clients + usize::from(c < total % clients);
            let coord = &*coordinator;
            handles.push(scope.spawn(move || {
                let mut rng = Pcg32::new(seed, c as u64 + 1);
                let mut lats = Vec::with_capacity(share);
                let (mut done, mut rej) = (0usize, 0usize);
                for _ in 0..share {
                    let model = if models.is_empty() {
                        None
                    } else {
                        let i = rng.below(models.len() as u32) as usize;
                        Some(models[i].clone())
                    };
                    let task = if tasks.is_empty() {
                        Task::MlmPredict
                    } else {
                        tasks[rng.below(tasks.len() as u32) as usize]
                    };
                    // respect the targeted model's own length ceiling
                    // (the default model's too, when none is named —
                    // its max_len may sit below the largest bucket)
                    let mut cap = max_len;
                    if let Some(reg) = coord.registry() {
                        let name = model
                            .as_deref()
                            .unwrap_or_else(|| coord.default_model());
                        if let Some(entry) = reg.get(name) {
                            cap = cap.min(entry.cfg.max_len);
                        }
                    }
                    let len = 1 + rng.below(cap as u32) as usize;
                    let tokens = corpus.sequence(len, 0, &mut rng);
                    let opts = SubmitOptions {
                        model,
                        task,
                        ..SubmitOptions::default()
                    };
                    match coord.submit_with(tokens, opts) {
                        Ok(ticket) => {
                            match ticket
                                .wait_timeout(Duration::from_secs(120))
                            {
                                Ok(resp)
                                    if resp.outcome == Outcome::Served =>
                                {
                                    done += 1;
                                    lats.push(resp.latency_s);
                                }
                                _ => rej += 1,
                            }
                        }
                        Err(_) => rej += 1,
                    }
                }
                (done, rej, lats)
            }));
        }
        for h in handles {
            let (done, rej, lats) = h.join().expect("client thread");
            completed += done;
            rejected += rej;
            latencies.extend(lats);
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<f64>() / latencies.len() as f64
    };
    let p95 = crate::util::stats::percentile(&latencies, 0.95);
    LoadReport {
        sent: total,
        completed,
        rejected,
        wall_s: wall,
        throughput_rps: completed as f64 / wall,
        mean_latency_s: mean,
        p95_latency_s: p95,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockRunner;

    #[test]
    fn load_generator_round_trips_with_mock() {
        let mk = |len: usize, cap: usize| {
            let factory: RunnerFactory = Box::new(move || {
                Ok(Box::new(MockRunner {
                    capacity: cap,
                    len,
                    delay: Duration::from_millis(1),
                    fail: false,
                }) as Box<dyn BatchRunner>)
            });
            (BucketSpec { max_len: len, batch: cap }, factory)
        };
        let coord =
            Coordinator::start(vec![mk(32, 4), mk(128, 2)], default_config(32));
        let report = run_load(&coord, 256, 40, 4, 11);
        assert_eq!(report.sent, 40);
        assert_eq!(report.completed + report.rejected, 40);
        assert!(report.completed > 0);
        assert!(report.throughput_rps > 0.0);
        coord.shutdown();
    }

    #[test]
    fn reference_coordinator_serves_end_to_end() {
        let cfg = crate::model::ModelConfig::tiny();
        let params = Arc::new(crate::model::Params::init(&cfg, 3));
        let coord = build_reference_coordinator(
            &cfg,
            &params,
            &[(16, 4), (cfg.max_len, 2)],
            BatcherConfig {
                max_delay: Duration::from_millis(2),
                ..Default::default()
            },
        );
        // short request routes to the small bucket, long to the big one
        let short = coord.submit(vec![1, 2, 3]).unwrap();
        let long = coord.submit(vec![4; 24]).unwrap();
        let rs = short.wait_timeout(Duration::from_secs(30)).unwrap();
        let rl = long.wait_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(rs.predictions.len(), 3);
        assert_eq!(rs.bucket_len, 16);
        assert_eq!(&*rs.model, "default");
        assert!(rs.generation > 0, "reference path tags the generation");
        assert_eq!(rl.predictions.len(), 24);
        assert_eq!(rl.bucket_len, cfg.max_len);
        assert!(rs
            .predictions
            .iter()
            .all(|&p| (p as usize) < cfg.vocab_size));
        coord.shutdown();
    }

    #[test]
    fn registry_coordinator_serves_two_models_and_tasks() {
        // the multi-tenant assembly: two registered models behind one
        // scheduler, requests addressing either, on two task kinds
        let registry = Arc::new(ModelRegistry::new());
        let small = crate::model::ModelConfig::tiny(); // max_len 32
        let mut big = small.clone();
        big.max_len = 64;
        big.d_model = 32;
        registry.register_init("small", small.clone(), 1).unwrap();
        registry.register_init("big", big.clone(), 2).unwrap();
        let coord = build_registry_coordinator(
            Arc::clone(&registry),
            &[(32, 4), (64, 2)],
            BatcherConfig {
                max_delay: Duration::from_millis(2),
                ..Default::default()
            },
        );
        assert_eq!(coord.default_model(), "small");
        let a = coord
            .submit_with(vec![1; 8], SubmitOptions::model("small"))
            .unwrap();
        let b = coord
            .submit_with(
                vec![2; 40],
                SubmitOptions::model_task("big", Task::Classify { head: 0 }),
            )
            .unwrap();
        let ra = a.wait_timeout(Duration::from_secs(30)).unwrap();
        let rb = b.wait_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(ra.outcome, Outcome::Served);
        assert_eq!(ra.generation, registry.get("small").unwrap().generation());
        assert_eq!(rb.outcome, Outcome::Served);
        assert_eq!(rb.generation, registry.get("big").unwrap().generation());
        assert_eq!(rb.predictions.len(), 1, "classify yields one class id");
        // a 40-token request can only fit the big model
        assert!(matches!(
            coord.submit_with(vec![1; 40], SubmitOptions::model("small")),
            Err(crate::coordinator::Reject::TooLong { max: 32, .. })
        ));
        coord.shutdown();
    }

    #[test]
    fn reference_coordinator_handles_concurrent_load() {
        let cfg = crate::model::ModelConfig::tiny();
        let params = Arc::new(crate::model::Params::init(&cfg, 4));
        let coord = build_reference_coordinator(
            &cfg,
            &params,
            &[(cfg.max_len, 4)],
            default_config(cfg.k_proj),
        );
        let report = run_load(&coord, cfg.vocab_size, 24, 3, 7);
        assert_eq!(report.completed + report.rejected, 24);
        assert!(report.completed >= 20, "too many failures: {report:?}");
        assert!(coord.metrics.occupancy() > 0.0);
        coord.shutdown();
    }

    #[test]
    fn reference_coordinator_shares_params_across_buckets() {
        // three buckets, one registry entry: runners hold the registry,
        // not weight clones — the only owners of the flat store are the
        // caller and the registry entry, however many buckets exist, and
        // shutdown releases the registry's
        let cfg = crate::model::ModelConfig::tiny();
        let params = Arc::new(crate::model::Params::init(&cfg, 5));
        let coord = build_reference_coordinator(
            &cfg,
            &params,
            &[(8, 2), (16, 2), (cfg.max_len, 2)],
            BatcherConfig {
                max_delay: Duration::from_millis(1),
                ..Default::default()
            },
        );
        for len in [4usize, 12, 24] {
            let t = coord.submit(vec![1; len]).unwrap();
            let r = t.wait_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(r.predictions.len(), len);
        }
        assert_eq!(
            Arc::strong_count(&params),
            2,
            "expected exactly one shared copy inside the registry"
        );
        coord.shutdown();
        assert_eq!(Arc::strong_count(&params), 1);
    }

    #[test]
    fn default_config_uses_linear_cost_and_edf() {
        let c = default_config(64);
        assert!(c.merge_up);
        assert_eq!(c.cost_model, CostModel::Linear { k: 64 });
        assert_eq!(c.policy, crate::coordinator::SchedPolicy::Edf);
        assert!(c.admission);
        assert!(c.shed_expired);
    }
}
