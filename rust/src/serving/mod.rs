//! Serving assembly: wire manifest artifacts (PJRT) or the pure-Rust
//! reference encoder into a running [`Coordinator`] (bucket per model),
//! plus a synthetic client-load generator used by the examples and
//! benches.

use std::sync::Arc;
use std::time::{Duration, Instant};

pub mod config;
pub mod trace;

pub use config::LauncherConfig;

#[cfg(feature = "pjrt")]
use crate::coordinator::{
    LocalBatchRunner, LocalRunnerFactory, PinnedRunner, XlaRunner,
};
use crate::coordinator::{
    BatchRunner, BatcherConfig, BucketSpec, Coordinator, CostModel,
    ReferenceRunner, RunnerFactory,
};
use crate::data::{Corpus, CorpusConfig};
use crate::model::{ModelConfig, Params};
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, Manifest};
#[cfg(feature = "pjrt")]
use crate::training::TrainError;
use crate::util::rng::Pcg32;

/// Build a coordinator whose buckets are served by the pure-Rust batched
/// reference encoder — no artifacts, no PJRT.  `buckets` lists
/// `(max_len, batch_capacity)` pairs; every bucket shares `cfg` and the
/// *same* `Arc<Params>` (one copy of the weights in memory regardless of
/// bucket count) and every bucket length must be ≤ `cfg.max_len`.  All
/// bucket workers draw their compute from the process-wide pool, so
/// concurrently-busy buckets never oversubscribe the thread budget.  This
/// is the serving path on machines without the `pjrt` feature, and the
/// end-to-end harness for `encode_batch`.
pub fn build_reference_coordinator(
    cfg: &ModelConfig,
    params: &Arc<Params>,
    buckets: &[(usize, usize)],
    config: BatcherConfig,
) -> Coordinator {
    assert!(!buckets.is_empty(), "at least one bucket required");
    let mut sorted = buckets.to_vec();
    sorted.sort_by_key(|&(len, _)| len);
    let mut specs: Vec<(BucketSpec, RunnerFactory)> = Vec::new();
    for (len, cap) in sorted {
        // validate here, on the calling thread: the same assert inside
        // ReferenceRunner::new would only fire on the spawned worker,
        // leaving clients to time out instead of failing fast
        assert!(
            len <= cfg.max_len,
            "bucket length {len} exceeds model max_len {}",
            cfg.max_len
        );
        assert!(cap > 0, "bucket capacity must be positive");
        let cfg = cfg.clone();
        let params = Arc::clone(params);
        let factory: RunnerFactory = Box::new(move || {
            Ok(Box::new(ReferenceRunner::new(cfg, params, len, cap))
                as Box<dyn BatchRunner>)
        });
        specs.push((BucketSpec { max_len: len, batch: cap }, factory));
    }
    Coordinator::start(specs, config)
}

/// Build a coordinator from manifest models (ascending max_len buckets).
///
/// Each named model becomes one bucket backed by its `mlm_logits` program
/// and `init.bin` (or checkpoint) parameters.  PJRT handles are `!Send`,
/// so each bucket's [`XlaRunner`] is built inside a [`PinnedRunner`]: a
/// dedicated thread owns the engine + executable and the scheduler's
/// pool tasks forward batches to it.  All buckets are *launched* here,
/// before the coordinator starts, so their engine/compile work runs
/// concurrently (startup is the slowest compile, not the sum).
#[cfg(feature = "pjrt")]
pub fn build_coordinator(
    manifest: &Manifest,
    model_names: &[&str],
    config: BatcherConfig,
) -> Result<Coordinator, TrainError> {
    let mut entries: Vec<&crate::runtime::ModelEntry> = model_names
        .iter()
        .map(|n| manifest.model(n))
        .collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.config.max_len);
    let mut buckets: Vec<(BucketSpec, RunnerFactory)> = Vec::new();
    for entry in entries {
        let spec = BucketSpec {
            max_len: entry.config.max_len,
            batch: entry.batch,
        };
        let info = entry.program("mlm_logits")?.clone();
        let params = entry.load_init()?;
        let batch = entry.batch;
        let (len, vocab) = (entry.config.max_len, entry.config.vocab_size);
        let local: LocalRunnerFactory = Box::new(move || {
            let engine = Engine::cpu().map_err(|e| e.to_string())?;
            let exe = engine
                .load_program(&info)
                .map_err(|e| e.to_string())?;
            Ok(Box::new(XlaRunner::new(exe, params, batch, len, vocab))
                as Box<dyn LocalBatchRunner>)
        });
        // launch now (compiles start concurrently); the coordinator's
        // factory only waits for readiness
        let pending = PinnedRunner::launch(local)
            .map_err(crate::training::TrainError::Serving)?;
        let factory: RunnerFactory = Box::new(move || {
            Ok(Box::new(pending.wait()?) as Box<dyn BatchRunner>)
        });
        buckets.push((spec, factory));
    }
    Ok(Coordinator::start(buckets, config))
}

/// Default serving batcher config tuned for the Linformer cost model:
/// EDF scheduling, admission control and expiry shedding on.
pub fn default_config(k: usize) -> BatcherConfig {
    BatcherConfig {
        max_delay: Duration::from_millis(10),
        queue_capacity: 512,
        merge_up: true,
        cost_model: CostModel::Linear { k },
        ..BatcherConfig::default()
    }
}

/// Result of a synthetic load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub sent: usize,
    pub completed: usize,
    pub rejected: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub mean_latency_s: f64,
    pub p95_latency_s: f64,
}

/// Drive `total` requests with mixed lengths through the coordinator from
/// `clients` threads; lengths are sampled in [1, max_len].
pub fn run_load(
    coordinator: &Coordinator,
    vocab: usize,
    total: usize,
    clients: usize,
    seed: u64,
) -> LoadReport {
    let corpus = Arc::new(Corpus::new(
        CorpusConfig {
            vocab_words: vocab - crate::data::tokenizer::NUM_SPECIAL as usize,
            ..CorpusConfig::default()
        },
        seed,
    ));
    let max_len = coordinator.max_len();
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(total);
    let (mut completed, mut rejected) = (0usize, 0usize);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let corpus = Arc::clone(&corpus);
            let share =
                total / clients + usize::from(c < total % clients);
            let coord = &*coordinator;
            handles.push(scope.spawn(move || {
                let mut rng = Pcg32::new(seed, c as u64 + 1);
                let mut lats = Vec::with_capacity(share);
                let (mut done, mut rej) = (0usize, 0usize);
                for _ in 0..share {
                    let len = 1 + rng.below(max_len as u32) as usize;
                    let tokens = corpus.sequence(len, 0, &mut rng);
                    match coord.submit(tokens) {
                        Ok(ticket) => {
                            match ticket
                                .wait_timeout(Duration::from_secs(120))
                            {
                                Ok(resp) if !resp.predictions.is_empty() => {
                                    done += 1;
                                    lats.push(resp.latency_s);
                                }
                                _ => rej += 1,
                            }
                        }
                        Err(_) => rej += 1,
                    }
                }
                (done, rej, lats)
            }));
        }
        for h in handles {
            let (done, rej, lats) = h.join().expect("client thread");
            completed += done;
            rejected += rej;
            latencies.extend(lats);
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<f64>() / latencies.len() as f64
    };
    let p95 = crate::util::stats::percentile(&latencies, 0.95);
    LoadReport {
        sent: total,
        completed,
        rejected,
        wall_s: wall,
        throughput_rps: completed as f64 / wall,
        mean_latency_s: mean,
        p95_latency_s: p95,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockRunner;

    #[test]
    fn load_generator_round_trips_with_mock() {
        let mk = |len: usize, cap: usize| {
            let factory: RunnerFactory = Box::new(move || {
                Ok(Box::new(MockRunner {
                    capacity: cap,
                    len,
                    delay: Duration::from_millis(1),
                    fail: false,
                }) as Box<dyn BatchRunner>)
            });
            (BucketSpec { max_len: len, batch: cap }, factory)
        };
        let coord =
            Coordinator::start(vec![mk(32, 4), mk(128, 2)], default_config(32));
        let report = run_load(&coord, 256, 40, 4, 11);
        assert_eq!(report.sent, 40);
        assert_eq!(report.completed + report.rejected, 40);
        assert!(report.completed > 0);
        assert!(report.throughput_rps > 0.0);
        coord.shutdown();
    }

    #[test]
    fn reference_coordinator_serves_end_to_end() {
        let cfg = crate::model::ModelConfig::tiny();
        let params = Arc::new(crate::model::Params::init(&cfg, 3));
        let coord = build_reference_coordinator(
            &cfg,
            &params,
            &[(16, 4), (cfg.max_len, 2)],
            BatcherConfig {
                max_delay: Duration::from_millis(2),
                ..Default::default()
            },
        );
        // short request routes to the small bucket, long to the big one
        let short = coord.submit(vec![1, 2, 3]).unwrap();
        let long = coord.submit(vec![4; 24]).unwrap();
        let rs = short.wait_timeout(Duration::from_secs(30)).unwrap();
        let rl = long.wait_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(rs.predictions.len(), 3);
        assert_eq!(rs.bucket_len, 16);
        assert_eq!(rl.predictions.len(), 24);
        assert_eq!(rl.bucket_len, cfg.max_len);
        assert!(rs
            .predictions
            .iter()
            .all(|&p| (p as usize) < cfg.vocab_size));
        coord.shutdown();
    }

    #[test]
    fn reference_coordinator_handles_concurrent_load() {
        let cfg = crate::model::ModelConfig::tiny();
        let params = Arc::new(crate::model::Params::init(&cfg, 4));
        let coord = build_reference_coordinator(
            &cfg,
            &params,
            &[(cfg.max_len, 4)],
            default_config(cfg.k_proj),
        );
        let report = run_load(&coord, cfg.vocab_size, 24, 3, 7);
        assert_eq!(report.completed + report.rejected, 24);
        assert!(report.completed >= 20, "too many failures: {report:?}");
        assert!(coord.metrics.occupancy() > 0.0);
        coord.shutdown();
    }

    #[test]
    fn reference_coordinator_shares_params_across_buckets() {
        // three buckets, one Arc<Params>: after every bucket has served a
        // request (so every runner exists), the only copies of the
        // weights are Arc refs — 1 here + 1 per runner — and shutdown
        // releases them all
        let cfg = crate::model::ModelConfig::tiny();
        let params = Arc::new(crate::model::Params::init(&cfg, 5));
        let coord = build_reference_coordinator(
            &cfg,
            &params,
            &[(8, 2), (16, 2), (cfg.max_len, 2)],
            BatcherConfig {
                max_delay: Duration::from_millis(1),
                ..Default::default()
            },
        );
        for len in [4usize, 12, 24] {
            let t = coord.submit(vec![1; len]).unwrap();
            let r = t.wait_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(r.predictions.len(), len);
        }
        assert_eq!(
            Arc::strong_count(&params),
            1 + 3,
            "expected exactly one Arc ref per bucket runner"
        );
        coord.shutdown();
        assert_eq!(Arc::strong_count(&params), 1);
    }

    #[test]
    fn default_config_uses_linear_cost_and_edf() {
        let c = default_config(64);
        assert!(c.merge_up);
        assert_eq!(c.cost_model, CostModel::Linear { k: 64 });
        assert_eq!(c.policy, crate::coordinator::SchedPolicy::Edf);
        assert!(c.admission);
        assert!(c.shed_expired);
    }
}
