//! Launcher configuration: TOML files → typed runtime configs.
//!
//! One file configures the whole deployment (see `configs/serve.toml`):
//!
//! ```toml
//! [serving]
//! models = ["tiny", "serve_128"]   # PJRT path: manifest bucket models
//! queue_capacity = 512
//! max_delay_ms = 10
//! merge_up = true
//! cost_model = "linear"        # or "quadratic"
//! cost_k = 32
//! policy = "edf"               # or "fifo"
//! admission = true             # deadline admission control
//! shed_expired = true          # drop expired queued requests
//! max_inflight = 2             # in-flight batches per bucket
//!
//! # Reference path: each [[model]] table registers one named model in
//! # the coordinator's ModelRegistry (first table = the default model).
//! # Weights come from a checkpoint's `params` slot, or a seeded init
//! # when no checkpoint is given.  `repro reload` swaps them live.
//! # `dtype` picks the inference flavor: "f32" (default) or "int8"
//! # (weights quantized per output channel at registration, activations
//! # per tensor at run time — ~4× less weight traffic, bounded accuracy
//! # cost; see ROADMAP Performance).
//! # `attention` picks the model's attention backend: "linformer"
//! # (default), "standard", "nystrom" or "linear-attn" — one registry
//! # can serve different mechanisms side by side (docs/ATTENTION.md).
//! [[model]]
//! name = "tiny"
//! seed = 0
//!
//! [[model]]
//! name = "longdoc"
//! checkpoint = "ckpt/longdoc.bin"
//! dtype = "int8"
//! attention = "nystrom"
//!
//! [training]
//! steps = 200
//! peak_lr = 0.001
//! warmup = 20
//! eval_every = 25
//! ```

use std::time::Duration;

use crate::coordinator::{BatcherConfig, CostModel, SchedPolicy};
use crate::linalg::Dtype;
use crate::model::Attention;
use crate::training::{LrSchedule, TrainConfig};
use crate::util::json::Json;
use crate::util::toml;

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("toml: {0}")]
    Toml(#[from] toml::TomlError),
    #[error("config: {0}")]
    Invalid(String),
}

/// One `[[model]]` table: a named registry entry's weight source.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelTable {
    pub name: String,
    /// Checkpoint path holding a `params` slot; `None` = seeded init.
    pub checkpoint: Option<String>,
    /// Init seed when no checkpoint is given.
    pub seed: u64,
    /// Inference flavor (`f32` default, or `int8` quantized).
    pub dtype: Dtype,
    /// Attention backend this entry serves (`linformer` default).
    pub attention: Attention,
}

/// Parsed launcher file.
#[derive(Debug)]
pub struct LauncherConfig {
    pub models: Vec<String>,
    /// Registry entries for the reference path (`[[model]]` tables, in
    /// file order — the first is the coordinator's default model).
    pub model_tables: Vec<ModelTable>,
    pub batcher: BatcherConfig,
    pub train: TrainConfig,
    pub artifacts_dir: String,
}

impl Default for LauncherConfig {
    fn default() -> Self {
        LauncherConfig {
            models: vec!["tiny".into(), "serve_128".into()],
            model_tables: Vec::new(),
            batcher: BatcherConfig::default(),
            train: TrainConfig::default(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl LauncherConfig {
    pub fn from_file(path: &str) -> Result<LauncherConfig, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<LauncherConfig, ConfigError> {
        let root = toml::parse(text)?;
        let mut cfg = LauncherConfig::default();
        if let Some(dir) = root.get("artifacts").as_str() {
            cfg.artifacts_dir = dir.to_string();
        }
        let serving = root.get("serving");
        if !serving.is_null() {
            if let Some(models) = serving.get("models").as_arr() {
                cfg.models = models
                    .iter()
                    .filter_map(Json::as_str)
                    .map(String::from)
                    .collect();
                if cfg.models.is_empty() {
                    return Err(ConfigError::Invalid(
                        "serving.models must be non-empty".into(),
                    ));
                }
            }
            if let Some(c) = serving.get("queue_capacity").as_usize() {
                cfg.batcher.queue_capacity = c;
            }
            if let Some(ms) = serving.get("max_delay_ms").as_f64() {
                cfg.batcher.max_delay = Duration::from_micros(
                    (ms * 1000.0) as u64,
                );
            }
            if let Some(m) = serving.get("merge_up").as_bool() {
                cfg.batcher.merge_up = m;
            }
            let k = serving.get("cost_k").as_usize().unwrap_or(32);
            match serving.get("cost_model").as_str() {
                Some("linear") | None => {
                    cfg.batcher.cost_model = CostModel::Linear { k };
                }
                Some("quadratic") => {
                    cfg.batcher.cost_model = CostModel::Quadratic;
                }
                Some(o) => {
                    return Err(ConfigError::Invalid(format!(
                        "unknown cost_model '{o}'"
                    )))
                }
            }
            match serving.get("policy").as_str() {
                Some("edf") | None => {
                    cfg.batcher.policy = SchedPolicy::Edf;
                }
                Some("fifo") => {
                    cfg.batcher.policy = SchedPolicy::Fifo;
                }
                Some(o) => {
                    return Err(ConfigError::Invalid(format!(
                        "unknown policy '{o}'"
                    )))
                }
            }
            if let Some(a) = serving.get("admission").as_bool() {
                cfg.batcher.admission = a;
            }
            if let Some(s) = serving.get("shed_expired").as_bool() {
                cfg.batcher.shed_expired = s;
            }
            if let Some(n) = serving.get("max_inflight").as_usize() {
                if n == 0 {
                    return Err(ConfigError::Invalid(
                        "serving.max_inflight must be ≥ 1".into(),
                    ));
                }
                cfg.batcher.max_inflight = n;
            }
        }
        if let Some(tables) = root.get("model").as_arr() {
            for (i, t) in tables.iter().enumerate() {
                let name = t
                    .get("name")
                    .as_str()
                    .ok_or_else(|| {
                        ConfigError::Invalid(format!(
                            "[[model]] table {i} is missing 'name'"
                        ))
                    })?
                    .to_string();
                if cfg.model_tables.iter().any(|m| m.name == name) {
                    return Err(ConfigError::Invalid(format!(
                        "duplicate [[model]] name '{name}'"
                    )));
                }
                let dtype = match t.get("dtype").as_str() {
                    None => Dtype::F32,
                    Some(s) => Dtype::from_name(s).ok_or_else(|| {
                        ConfigError::Invalid(format!(
                            "[[model]] '{name}': unknown dtype '{s}' \
                             (expected \"f32\" or \"int8\")"
                        ))
                    })?,
                };
                let attention = match t.get("attention").as_str() {
                    None => Attention::Linformer,
                    Some(s) => {
                        Attention::from_name(s).ok_or_else(|| {
                            ConfigError::Invalid(format!(
                                "[[model]] '{name}': unknown attention \
                                 '{s}' (expected {})",
                                Attention::VALID
                            ))
                        })?
                    }
                };
                cfg.model_tables.push(ModelTable {
                    name,
                    checkpoint: t
                        .get("checkpoint")
                        .as_str()
                        .map(String::from),
                    seed: t.get("seed").as_usize().unwrap_or(0) as u64,
                    dtype,
                    attention,
                });
            }
        }
        let training = root.get("training");
        if !training.is_null() {
            let steps = training
                .get("steps")
                .as_usize()
                .unwrap_or(cfg.train.steps);
            let peak = training
                .get("peak_lr")
                .as_f64()
                .unwrap_or(1e-3) as f32;
            let warmup = training
                .get("warmup")
                .as_usize()
                .unwrap_or(steps / 10);
            if warmup > steps {
                return Err(ConfigError::Invalid(
                    "training.warmup exceeds steps".into(),
                ));
            }
            cfg.train.steps = steps;
            cfg.train.schedule = LrSchedule::linear(peak, warmup, steps);
            if let Some(e) = training.get("eval_every").as_usize() {
                cfg.train.eval_every = e;
            }
            if let Some(s) = training.get("seed").as_usize() {
                cfg.train.seed = s as u64;
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let c = LauncherConfig::from_toml("").unwrap();
        assert_eq!(c.models, vec!["tiny", "serve_128"]);
        assert_eq!(c.artifacts_dir, "artifacts");
        assert_eq!(c.batcher.policy, SchedPolicy::Edf);
        assert!(c.batcher.admission);
        assert!(c.batcher.shed_expired);
    }

    #[test]
    fn full_file_parses() {
        let c = LauncherConfig::from_toml(
            r#"
            artifacts = "my_artifacts"
            [serving]
            models = ["a", "b"]
            queue_capacity = 99
            max_delay_ms = 2.5
            merge_up = false
            cost_model = "quadratic"
            policy = "fifo"
            admission = false
            shed_expired = false
            max_inflight = 4
            [training]
            steps = 77
            peak_lr = 0.01
            warmup = 7
            eval_every = 11
            seed = 5
            "#,
        )
        .unwrap();
        assert_eq!(c.models, vec!["a", "b"]);
        assert_eq!(c.batcher.queue_capacity, 99);
        assert_eq!(c.batcher.max_delay, Duration::from_micros(2500));
        assert!(!c.batcher.merge_up);
        assert_eq!(c.batcher.cost_model, CostModel::Quadratic);
        assert_eq!(c.batcher.policy, SchedPolicy::Fifo);
        assert!(!c.batcher.admission);
        assert!(!c.batcher.shed_expired);
        assert_eq!(c.batcher.max_inflight, 4);
        assert_eq!(c.train.steps, 77);
        assert_eq!(c.train.eval_every, 11);
        assert_eq!(c.train.seed, 5);
        assert!((c.train.schedule.at(77) - 0.0).abs() < 1e-9);
        assert_eq!(c.artifacts_dir, "my_artifacts");
    }

    #[test]
    fn rejects_bad_cost_model_and_warmup() {
        assert!(LauncherConfig::from_toml(
            "[serving]\ncost_model = \"cubic\""
        )
        .is_err());
        assert!(LauncherConfig::from_toml(
            "[serving]\npolicy = \"random\""
        )
        .is_err());
        assert!(LauncherConfig::from_toml(
            "[serving]\nmax_inflight = 0"
        )
        .is_err());
        assert!(LauncherConfig::from_toml(
            "[training]\nsteps = 5\nwarmup = 10"
        )
        .is_err());
        assert!(LauncherConfig::from_toml("[serving]\nmodels = []").is_err());
    }

    #[test]
    fn model_tables_parse_in_order() {
        let c = LauncherConfig::from_toml(
            r#"
            [serving]
            queue_capacity = 7
            [[model]]
            name = "tiny"
            seed = 3
            [[model]]
            name = "longdoc"
            checkpoint = "ckpt/longdoc.bin"
            dtype = "int8"
            attention = "nystrom"
            "#,
        )
        .unwrap();
        assert_eq!(c.batcher.queue_capacity, 7);
        assert_eq!(
            c.model_tables,
            vec![
                ModelTable {
                    name: "tiny".into(),
                    checkpoint: None,
                    seed: 3,
                    dtype: Dtype::F32,
                    attention: Attention::Linformer,
                },
                ModelTable {
                    name: "longdoc".into(),
                    checkpoint: Some("ckpt/longdoc.bin".into()),
                    seed: 0,
                    dtype: Dtype::Int8,
                    attention: Attention::Nystrom,
                },
            ]
        );
        // nameless and duplicate-name tables are config errors
        assert!(LauncherConfig::from_toml("[[model]]\nseed = 1").is_err());
        assert!(LauncherConfig::from_toml(
            "[[model]]\nname = \"a\"\n[[model]]\nname = \"a\""
        )
        .is_err());
    }

    #[test]
    fn model_table_dtype_parses_and_rejects_unknown() {
        let c = LauncherConfig::from_toml(
            "[[model]]\nname = \"a\"\ndtype = \"f32\"",
        )
        .unwrap();
        assert_eq!(c.model_tables[0].dtype, Dtype::F32);
        let c = LauncherConfig::from_toml(
            "[[model]]\nname = \"a\"\ndtype = \"int8\"",
        )
        .unwrap();
        assert_eq!(c.model_tables[0].dtype, Dtype::Int8);
        let err = LauncherConfig::from_toml(
            "[[model]]\nname = \"a\"\ndtype = \"fp16\"",
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown dtype"), "{err}");
    }

    #[test]
    fn model_table_attention_parses_and_rejects_unknown() {
        for (s, want) in [
            ("standard", Attention::Standard),
            ("linformer", Attention::Linformer),
            ("nystrom", Attention::Nystrom),
            ("linear-attn", Attention::LinearAttn),
        ] {
            let c = LauncherConfig::from_toml(&format!(
                "[[model]]\nname = \"a\"\nattention = \"{s}\""
            ))
            .unwrap();
            assert_eq!(c.model_tables[0].attention, want);
        }
        // default is the repo's namesake mechanism
        let c = LauncherConfig::from_toml("[[model]]\nname = \"a\"")
            .unwrap();
        assert_eq!(c.model_tables[0].attention, Attention::Linformer);
        // unknown strings are rejected with the valid values named,
        // not silently defaulted
        let err = LauncherConfig::from_toml(
            "[[model]]\nname = \"a\"\nattention = \"performer\"",
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown attention 'performer'"), "{msg}");
        assert!(msg.contains("linear-attn"), "{msg}");
        assert!(msg.contains("nystrom"), "{msg}");
    }

    #[test]
    fn linear_cost_k_applied() {
        let c = LauncherConfig::from_toml(
            "[serving]\ncost_model = \"linear\"\ncost_k = 64",
        )
        .unwrap();
        assert_eq!(c.batcher.cost_model, CostModel::Linear { k: 64 });
    }
}
