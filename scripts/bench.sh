#!/usr/bin/env bash
# One-command bench runner for the encoder + serving measurement suite.
#
# Runs every JSON-emitting bench in one invocation and merges their
# records into the trajectory logs next to Cargo.toml:
#
#   BENCH_encoder.json   <- fig2_inference (kernel A/B, cached f32/int8
#                           panels, the fusion-regime triple
#                           full / softmax-only / none on both dtypes,
#                           and the cross-mechanism ns/token frontier:
#                           standard / linformer / nystrom / linear-attn
#                           x both dtypes, in the one invocation — every
#                           record carries a `mechanism` tag)
#                           + table3_efficiency (speedup grid under both
#                           kernels and all three fusion regimes)
#   BENCH_serving.json   <- coordinator (multi-tenant serving latencies)
#
# Each bench owns one top-level section of its file (write-then-rename
# via `emit_bench_json`), so re-running refreshes in place and never
# clobbers the other sections.
#
# Usage: scripts/bench.sh [encoder|serving|all]    (default: all)

set -euo pipefail
cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench.sh: cargo not found on PATH — install a Rust toolchain" >&2
    exit 127
fi

what="${1:-all}"
case "$what" in
encoder | serving | all) ;;
*)
    echo "usage: scripts/bench.sh [encoder|serving|all]" >&2
    exit 2
    ;;
esac

if [ "$what" = "encoder" ] || [ "$what" = "all" ]; then
    echo "== bench: fig2_inference (BENCH_encoder.json) =="
    cargo bench --bench fig2_inference
    echo
    echo "== bench: table3_efficiency (BENCH_encoder.json) =="
    cargo bench --bench table3_efficiency
fi

if [ "$what" = "serving" ] || [ "$what" = "all" ]; then
    echo
    echo "== bench: coordinator (BENCH_serving.json) =="
    cargo bench --bench coordinator
fi

echo
echo "== bench logs =="
for f in BENCH_encoder.json BENCH_serving.json; do
    if [ -f "$f" ]; then
        echo "  $(pwd)/$f"
    fi
done
