#!/usr/bin/env bash
# Repo check: formatting (advisory), the repro-lint invariant pass,
# clippy correctness lints, the tier-1 gate
# (`cargo build --release && cargo test -q`), the release-mode property
# suites, and — where the toolchain allows — Miri over the unsafe
# pool/kernel core plus an opt-in ThreadSanitizer pool stress stage.
#
# Usage: scripts/check.sh [--fix]
#   --fix        run `cargo fmt` for real instead of just reporting drift
#   REPRO_TSAN=1 additionally run pool_stress under ThreadSanitizer
#                (needs nightly + rust-src; skipped loudly otherwise)

set -euo pipefail
cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "[check] error: cargo not found on PATH" >&2
    exit 127
fi

if [[ "${1:-}" == "--fix" ]]; then
    cargo fmt --all
else
    # advisory: the tree predates rustfmt adoption, so drift is reported
    # but does not fail the check
    if ! cargo fmt --all --check >/dev/null 2>&1; then
        echo "[check] note: rustfmt drift detected (run scripts/check.sh --fix)"
    fi
fi

# repro-lint: the repo-invariant static pass (documented unsafe,
# pool-only threading, zero-alloc hot-path regions, fma fencing, the
# batcher's once-per-tick time discipline — see docs/INVARIANTS.md).
# Runs before the release build so violations fail fast; exits non-zero
# on any finding.
cargo run --quiet --bin repro_lint || {
    echo "[check] repro-lint found invariant violations" >&2
    exit 1
}

# deny the lints that flag real bugs; style lints stay advisory.
# clippy::perf is denied too so the linalg/model hot paths cannot regrow
# hidden allocations or copies (any perf lint anywhere fails the check —
# the tree is clean of them as of the compute-pool PR), and
# clippy::suspicious so almost-certain logic slips (swapped operands in
# op impls, float comparisons missing abs, mutated range bounds, …)
# cannot land either.
if command -v cargo-clippy >/dev/null 2>&1 || cargo clippy --version >/dev/null 2>&1; then
    # -A first, -D second: lint-level flags are last-wins per lint, so
    # the deny must come after the blanket allow to actually deny
    cargo clippy --all-targets --quiet -- \
        -A clippy::all -D clippy::correctness -D clippy::suspicious \
        -D clippy::perf || {
        echo "[check] clippy correctness/suspicious/perf lints failed" >&2
        exit 1
    }
else
    echo "[check] note: clippy unavailable, skipping lints"
fi

# Miri over the unsafe core: the pool's scoped-lifetime transmute
# (pool.rs, Task<'env> -> StaticTask) and every PanelBuf raw-slice
# reinterpret (kernel.rs flat/flat_mut) get exercised under the
# interpreter's aliasing + validity checks via the linalg::pool and
# linalg::kernel unit tests.  Needs a nightly toolchain with the miri
# component; degrades to a loud skip-note on stable-only machines,
# exactly like the clippy guard above.
if cargo +nightly miri --version >/dev/null 2>&1; then
    echo "[check] miri: linalg::pool + linalg::kernel unit tests"
    # isolation off: the pool tests read LINFORMER_THREADS and the clock
    MIRIFLAGS="-Zmiri-disable-isolation" \
        cargo +nightly miri test --lib -q -- linalg::pool linalg::kernel || {
        echo "[check] miri stage failed" >&2
        exit 1
    }
else
    echo "[check] note: nightly+miri unavailable, skipping the miri stage"
fi

# tier-1
cargo build --release
cargo test -q

# the pool stress test forces parallel-threshold GEMMs from several
# concurrent buckets; debug-mode kernels would dominate its runtime, so
# it is #[ignore]d under tier-1 and run here in release
cargo test --release --test pool_stress -- --ignored

# opt-in ThreadSanitizer pass over the same stress test: catches data
# races the helping-worker drain or a future pool change could
# introduce.  Opt-in (REPRO_TSAN=1) because -Zbuild-std multiplies
# build time; needs nightly with the rust-src component and degrades to
# a loud skip-note without it.
if [[ "${REPRO_TSAN:-0}" == "1" ]]; then
    host="$(rustc -vV | sed -n 's/^host: //p')"
    if rustup component list --toolchain nightly 2>/dev/null \
        | grep -q '^rust-src.*(installed)'; then
        echo "[check] tsan: pool_stress on ${host}"
        RUSTFLAGS="-Zsanitizer=thread" \
            cargo +nightly test --release -Zbuild-std \
            --target "${host}" --test pool_stress -- --ignored || {
            echo "[check] tsan stage failed" >&2
            exit 1
        }
    else
        echo "[check] note: REPRO_TSAN=1 but nightly rust-src is" \
            "unavailable, skipping the tsan stage"
    fi
fi

# SIMD microkernel property tests: hundreds of random odd-shaped GEMMs
# vs the f64 naive reference, the scalar kernel (bitwise on A·B paths)
# and every thread plan, plus the axpy/dot remainder-lane sweep — too
# slow for debug tier-1 (a smoke case runs there), full sweep in release
cargo test --release --test kernel_prop -- --ignored

# attention-regime property tests: random ragged lengths across all
# four projection flavors, checked bitwise across thread budgets
# {1, 2, 8}, head-serial vs head-parallel fan-out, fused-epilogue vs
# standalone softmax, and the capture path (a smoke case runs in tier-1)
cargo test --release --test attn_prop -- --ignored

# int8 quantized-path property tests: random shapes vs the spec-replay
# oracle (bitwise), the analytic quantization-error bound, thread-count
# determinism, and f32-panel/unpacked bitwise equivalence
cargo test --release --test int8_kernel_prop -- --ignored

# int8 end-to-end accuracy gate: MLM argmax agreement + bounded max
# relative logit error of the quantized path vs the f32 reference,
# both served through the generation-keyed packed-panel cache
cargo test --release --test int8_accuracy -- --ignored

# the scheduler overload ablation is timing-sensitive (burst trace vs
# SLOs), so it also runs in release only: FIFO must miss deadlines, EDF
# must shed instead of computing expired work
cargo test --release --test scheduler_overload -- --ignored

# multi-tenant smoke in release: two models × two tasks through one
# scheduler (bitwise vs direct encoder) + hot-swap under live traffic
# (no dropped requests, no mixed-generation batches)
cargo test --release --test multi_tenant

echo "[check] OK"
