#!/usr/bin/env bash
# Repo check: formatting (advisory), clippy correctness lints, and the
# tier-1 gate (`cargo build --release && cargo test -q`).
#
# Usage: scripts/check.sh [--fix]
#   --fix   run `cargo fmt` for real instead of just reporting drift

set -euo pipefail
cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "[check] error: cargo not found on PATH" >&2
    exit 127
fi

if [[ "${1:-}" == "--fix" ]]; then
    cargo fmt --all
else
    # advisory: the tree predates rustfmt adoption, so drift is reported
    # but does not fail the check
    if ! cargo fmt --all --check >/dev/null 2>&1; then
        echo "[check] note: rustfmt drift detected (run scripts/check.sh --fix)"
    fi
fi

# deny the lints that flag real bugs; style lints stay advisory.
# clippy::perf is denied too so the linalg/model hot paths cannot regrow
# hidden allocations or copies (any perf lint anywhere fails the check —
# the tree is clean of them as of the compute-pool PR).
if command -v cargo-clippy >/dev/null 2>&1 || cargo clippy --version >/dev/null 2>&1; then
    # -A first, -D second: lint-level flags are last-wins per lint, so
    # the deny must come after the blanket allow to actually deny
    cargo clippy --all-targets --quiet -- \
        -A clippy::all -D clippy::correctness -D clippy::perf || {
        echo "[check] clippy correctness/perf lints failed" >&2
        exit 1
    }
else
    echo "[check] note: clippy unavailable, skipping lints"
fi

# tier-1
cargo build --release
cargo test -q

# the pool stress test forces parallel-threshold GEMMs from several
# concurrent buckets; debug-mode kernels would dominate its runtime, so
# it is #[ignore]d under tier-1 and run here in release
cargo test --release --test pool_stress -- --ignored

# SIMD microkernel property tests: hundreds of random odd-shaped GEMMs
# vs the f64 naive reference, the scalar kernel (bitwise on A·B paths)
# and every thread plan, plus the axpy/dot remainder-lane sweep — too
# slow for debug tier-1 (a smoke case runs there), full sweep in release
cargo test --release --test kernel_prop -- --ignored

# int8 quantized-path property tests: random shapes vs the spec-replay
# oracle (bitwise), the analytic quantization-error bound, thread-count
# determinism, and f32-panel/unpacked bitwise equivalence
cargo test --release --test int8_kernel_prop -- --ignored

# int8 end-to-end accuracy gate: MLM argmax agreement + bounded max
# relative logit error of the quantized path vs the f32 reference,
# both served through the generation-keyed packed-panel cache
cargo test --release --test int8_accuracy -- --ignored

# the scheduler overload ablation is timing-sensitive (burst trace vs
# SLOs), so it also runs in release only: FIFO must miss deadlines, EDF
# must shed instead of computing expired work
cargo test --release --test scheduler_overload -- --ignored

# multi-tenant smoke in release: two models × two tasks through one
# scheduler (bitwise vs direct encoder) + hot-swap under live traffic
# (no dropped requests, no mixed-generation batches)
cargo test --release --test multi_tenant

echo "[check] OK"
