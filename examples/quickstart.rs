//! Quickstart: the whole three-layer stack in ~60 lines.
//!
//! Loads the tiny Linformer artifact (AOT-compiled from the JAX/Pallas
//! model by `make artifacts`), runs a masked-token prediction through the
//! PJRT runtime, and trains it for a handful of steps — no Python at
//! runtime.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use linformer::data::tokenizer::MASK;
use linformer::runtime::{Engine, Manifest, Tensor};
use linformer::training::Trainer;
use linformer::util::rng::Pcg32;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Load the artifact manifest (the Python↔Rust contract).
    let manifest = Manifest::load("artifacts")?;
    let entry = manifest.model("tiny")?;
    println!(
        "model 'tiny': n={} k={} {:?} sharing, {} params",
        entry.config.max_len,
        entry.config.k_proj,
        entry.config.sharing,
        entry.param_count
    );

    // 2. Compile the MLM forward program on the PJRT CPU client.
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let exe = engine.load_program(entry.program("mlm_logits")?)?;
    println!("compiled mlm_logits in {:.2}s", exe.compile_time);

    // 3. Predict a masked token.
    let mut rng = Pcg32::seeded(0);
    let n = entry.config.max_len;
    let mut tokens: Vec<u32> = (0..n)
        .map(|_| 5 + rng.below(entry.config.vocab_size as u32 - 5))
        .collect();
    let masked_pos = 7;
    let original = tokens[masked_pos];
    tokens[masked_pos] = MASK;
    let batch: Vec<Vec<u32>> = vec![tokens; entry.batch];
    let params = entry.load_init()?;
    let out = exe.run(&[
        Tensor::F32 { shape: vec![params.len()], data: params },
        Tensor::tokens(&batch),
    ])?;
    let logits = out[0].as_f32()?;
    let vocab = entry.config.vocab_size;
    let row = &logits[masked_pos * vocab..(masked_pos + 1) * vocab];
    let pred = (0..vocab).max_by(|&a, &b| row[a].total_cmp(&row[b])).unwrap();
    println!(
        "masked position {masked_pos}: original id {original}, \
         predicted id {pred} (untrained — random is expected)"
    );

    // 4. Train for a few steps with the fused AdamW train_step artifact.
    let mut trainer = Trainer::new(&engine, entry)?;
    let mut rng = Pcg32::seeded(1);
    println!("training 10 steps…");
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 1..=10 {
        let loss = trainer.train_step(3e-3, &mut rng)?;
        if step == 1 {
            first = loss;
        }
        last = loss;
        println!("  step {step:>2}: loss {loss:.4}");
    }
    println!("loss {first:.4} → {last:.4} (should decrease)");
    Ok(())
}
