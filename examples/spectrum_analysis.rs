//! Fig 1 reproduction: spectrum analysis of self-attention matrices.
//!
//! Renders the paper's two panels as terminal plots:
//!  * left — normalized cumulative singular-value curve of the
//!    context-mapping matrix P, averaged over layers/heads/samples;
//!  * right — heatmap of the cumulative value at index n/4 per
//!    (layer, head) — higher layers should skew higher (lower rank).
//!
//! Run: `cargo run --release --example spectrum_analysis -- [--n 128]`

use linformer::analysis::{analyze, long_tail_score};
use linformer::model::{Attention, ModelConfig, Params};
use linformer::util::cli::Args;

fn bar(v: f32, width: usize) -> String {
    let filled = (v.clamp(0.0, 1.0) * width as f32) as usize;
    format!("{}{}", "█".repeat(filled), "░".repeat(width - filled))
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            ("n", "sequence length (default 128)"),
            ("layers", "layers (default 4)"),
            ("heads", "heads (default 4)"),
            ("samples", "sequences averaged (default 4)"),
        ],
    )?;
    let n = args.usize_or("n", 128)?;
    let layers = args.usize_or("layers", 4)?;
    let heads = args.usize_or("heads", 4)?;

    let mut cfg = ModelConfig::tiny();
    cfg.attention = Attention::Standard; // P is the n×n matrix of Thm 1
    cfg.max_len = n;
    cfg.n_layers = layers;
    cfg.n_heads = heads;
    cfg.d_model = 16 * heads;
    cfg.vocab_size = 2048;
    let params = Params::init(&cfg, 0);

    println!("== Fig 1 (left): cumulative spectrum of P, n={n} ==");
    let report = analyze(&params, &cfg, args.usize_or("samples", 4)?, 0);
    let mean = report.mean_cumulative();
    for frac in [0.02, 0.05, 0.1, 0.25, 0.5, 0.75, 1.0] {
        let idx = ((n as f64 * frac) as usize).clamp(1, n) - 1;
        let v = mean[idx.min(mean.len() - 1)];
        println!("  top {:>5.1}% svs | {} {v:.3}", frac * 100.0, bar(v, 40));
    }
    let score = long_tail_score(&report);
    println!(
        "\nlong-tail score (cumulative mass at n/4): {score:.3} \
         (flat spectrum would be 0.250)"
    );
    println!(
        "→ self-attention is approximately low-rank (paper Thm 1): {}",
        if score > 0.4 { "CONFIRMED" } else { "NOT OBSERVED" }
    );

    println!("\n== Fig 1 (right): cumulative@n/4 per layer × head ==");
    print!("{:>8}", "");
    for h in 0..heads {
        print!("  head{h}");
    }
    println!();
    for (l, row) in report.heatmap(layers, heads).iter().enumerate() {
        print!("layer {l:>2}");
        for v in row {
            print!("  {v:.3}");
        }
        println!();
    }
    Ok(())
}
