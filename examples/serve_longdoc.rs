//! Long-document serving scenario — the workload the paper's introduction
//! motivates (Linformer makes long-sequence inference affordable).
//!
//! Starts the coordinator with two length buckets (tiny n=64 + serve_128
//! n=128), drives a mixed short/long synthetic workload from concurrent
//! clients, and prints the throughput/latency/occupancy metrics the
//! coordinator collects.
//!
//! Run: `make artifacts && cargo run --release --example serve_longdoc`

use linformer::runtime::Manifest;
use linformer::serving;
use linformer::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            ("requests", "total requests (default 96)"),
            ("clients", "client threads (default 6)"),
            ("models", "comma-separated buckets (default tiny,serve_128)"),
        ],
    )?;
    let manifest = Manifest::load("artifacts")?;
    let names_s = args.str_or("models", "tiny,serve_128");
    let names: Vec<&str> = names_s.split(',').collect();

    println!("== long-document serving ==");
    for n in &names {
        let e = manifest.model(n)?;
        println!(
            "bucket {n}: n={}, batch={}, k={}",
            e.config.max_len, e.batch, e.config.k_proj
        );
    }
    println!("compiling executables on pinned runner threads…");
    let coord = serving::build_coordinator(
        &manifest,
        &names,
        serving::default_config(32),
    )?;

    // vocab of the smallest model bounds valid token ids for all buckets
    let vocab = names
        .iter()
        .map(|n| manifest.model(n).unwrap().config.vocab_size)
        .min()
        .unwrap();

    let total = args.usize_or("requests", 96)?;
    let clients = args.usize_or("clients", 6)?;
    println!("driving {total} requests from {clients} concurrent clients…");
    let report = serving::run_load(&coord, vocab, total, clients, 7);

    println!("\n== results ==");
    println!("completed     {}/{}", report.completed, report.sent);
    println!("rejected      {}", report.rejected);
    println!("wall time     {:.2}s", report.wall_s);
    println!("throughput    {:.1} req/s", report.throughput_rps);
    println!("mean latency  {:.1} ms", report.mean_latency_s * 1e3);
    println!("p95 latency   {:.1} ms", report.p95_latency_s * 1e3);
    println!("occupancy     {:.1}%", coord.metrics.occupancy() * 100.0);
    use std::sync::atomic::Ordering;
    println!(
        "shed/abandoned {}/{} (deadline scheduler drops, never computed)",
        coord.metrics.shed.load(Ordering::Relaxed),
        coord.metrics.abandoned.load(Ordering::Relaxed)
    );
    println!("metrics json  {}", coord.metrics.to_json());
    coord.shutdown();
    Ok(())
}
