//! Long-document serving scenario — the workload the paper's introduction
//! motivates (Linformer makes long-sequence inference affordable), now
//! multi-tenant: one coordinator serves a short-context "chat" model and
//! a long-context "longdoc" model concurrently, across task kinds, on
//! the pure-Rust reference encoder (no artifacts, no PJRT).
//!
//! Drives a mixed workload from concurrent clients, hot-swaps the
//! longdoc model's weights mid-run, and prints the per-model /
//! per-task / per-bucket metrics the coordinator collects.
//!
//! Run: `cargo run --release --example serve_longdoc`

use std::sync::Arc;

use linformer::coordinator::{ModelRegistry, Task};
use linformer::model::{ModelConfig, Params};
use linformer::serving;
use linformer::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            ("requests", "total requests (default 96)"),
            ("clients", "client threads (default 6)"),
            ("seed", "rng seed (default 7)"),
        ],
    )?;

    // two tenants: a short-context chat model and a long-document model
    let mut chat = ModelConfig::tiny();
    chat.max_len = 64;
    chat.d_model = 32;
    chat.k_proj = 16;
    chat.vocab_size = 512;
    let mut longdoc = chat.clone();
    longdoc.max_len = 256;
    longdoc.k_proj = 32;

    let registry = Arc::new(ModelRegistry::new());
    registry.register_init("chat", chat.clone(), 1)?;
    registry.register_init("longdoc", longdoc.clone(), 2)?;

    println!("== multi-tenant long-document serving ==");
    for name in registry.names() {
        let e = registry.get(&name).unwrap();
        println!(
            "model {name}: n={}, k={}, params={}, generation={}",
            e.cfg.max_len,
            e.cfg.k_proj,
            e.params.len(),
            e.generation()
        );
    }

    let coord = serving::build_registry_coordinator(
        Arc::clone(&registry),
        &[(64, 8), (256, 4)],
        serving::default_config(32),
    );

    let total = args.usize_or("requests", 96)?;
    let clients = args.usize_or("clients", 6)?;
    let seed = args.usize_or("seed", 7)? as u64;
    println!(
        "driving {total} requests from {clients} concurrent clients \
         (2 models × 3 tasks)…"
    );
    let models = vec!["chat".to_string(), "longdoc".to_string()];
    let tasks =
        [Task::MlmPredict, Task::Encode, Task::Classify { head: 0 }];
    let report = serving::run_load_mix(
        &coord,
        chat.vocab_size,
        total / 2,
        clients,
        seed,
        &models,
        &tasks,
    );

    // hot-swap the longdoc weights while the second half of the load is
    // in flight — in-flight batches keep their pinned generation, new
    // flushes pick up the fresh weights, nothing drops
    let report2 = std::thread::scope(|scope| {
        let coord = &coord;
        let (models, tasks) = (&models, &tasks);
        let second = scope.spawn(move || {
            serving::run_load_mix(
                coord,
                chat.vocab_size,
                total - total / 2,
                clients,
                seed + 1,
                models,
                tasks,
            )
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        let v = registry
            .reload("longdoc", Arc::new(Params::init(&longdoc, 99)))
            .expect("reload longdoc");
        println!(
            "hot-swapped longdoc mid-load → version {v} (generation {})",
            registry.get("longdoc").unwrap().generation()
        );
        second.join().expect("second load half")
    });

    println!("\n== results ==");
    let completed = report.completed + report2.completed;
    println!("completed     {completed}/{total}");
    println!("rejected      {}", report.rejected + report2.rejected);
    println!(
        "wall time     {:.2}s",
        report.wall_s + report2.wall_s
    );
    println!(
        "throughput    {:.1} req/s",
        completed as f64 / (report.wall_s + report2.wall_s)
    );
    // latency quantiles don't aggregate across halves; report each
    println!(
        "mean latency  {:.1} ms pre-swap / {:.1} ms post-swap",
        report.mean_latency_s * 1e3,
        report2.mean_latency_s * 1e3
    );
    println!(
        "p95 latency   {:.1} ms pre-swap / {:.1} ms post-swap",
        report.p95_latency_s * 1e3,
        report2.p95_latency_s * 1e3
    );
    println!("occupancy     {:.1}%", coord.metrics.occupancy() * 100.0);
    use std::sync::atomic::Ordering;
    println!(
        "shed/abandoned {}/{} (deadline scheduler drops, never computed)",
        coord.metrics.shed.load(Ordering::Relaxed),
        coord.metrics.abandoned.load(Ordering::Relaxed)
    );
    println!("metrics json  {}", coord.metrics.to_json());
    coord.shutdown();
    Ok(())
}
