//! Table 1 reproduction: per-layer complexity and sequential operations
//! for Recurrent / Transformer / Sparse / Reformer / Linformer, plus the
//! concrete FLOP and activation-byte counts our analytic model assigns at
//! a sweep of sequence lengths.
//!
//! Run: `cargo run --release --example complexity_table`

use linformer::analysis::complexity::{
    speedup_vs_transformer, table1, Arch,
};

fn main() {
    let d = 64;
    let k = 128;
    println!("== Table 1: complexity per layer (asymptotic) ==");
    println!("{:<22} {:>14} {:>18}", "architecture", "complexity", "seq. operations");
    for row in table1(512, d, k) {
        let seq = match row.arch {
            Arch::Recurrent => "O(n)",
            Arch::Reformer => "O(log n)",
            _ => "O(1)",
        };
        println!("{:<22} {:>14} {:>18}", row.arch.name(), row.complexity, seq);
    }

    println!("\n== concrete attention FLOPs (GFLOP, d={d}, k={k}) ==");
    let ns = [512usize, 1024, 2048, 4096, 16384, 65536];
    print!("{:<22}", "architecture");
    for n in ns {
        print!("{n:>10}");
    }
    println!();
    for arch in [
        Arch::Recurrent,
        Arch::Transformer,
        Arch::SparseTransformer,
        Arch::Reformer,
        Arch::Linformer { k },
    ] {
        print!("{:<22}", arch.name());
        for n in ns {
            print!("{:>10.2}", arch.attention_flops(n, d) / 1e9);
        }
        println!();
    }

    println!("\n== Linformer speedup over Transformer (FLOP ratio) ==");
    print!("{:<22}", "n");
    for n in ns {
        print!("{n:>10}");
    }
    println!();
    print!("{:<22}", "speedup");
    for n in ns {
        print!("{:>9.1}x", speedup_vs_transformer(n, d, k));
    }
    println!();
    println!(
        "\nLinformer is O(n) with O(1) sequential operations — the only row \
         achieving both (paper Table 1)."
    );
}
