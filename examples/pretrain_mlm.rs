//! End-to-end validation driver (DESIGN.md §4, EXPERIMENTS.md §E2E):
//! pretrain the serve_128 Linformer with the MLM objective on the
//! synthetic corpus for a few hundred steps and log the loss curve,
//! proving all three layers compose: Pallas kernels → JAX train_step HLO →
//! Rust data pipeline/scheduler → PJRT execution.
//!
//! Run: `make artifacts && cargo run --release --example pretrain_mlm -- \
//!        [--steps 300] [--model serve_128]`

use linformer::runtime::{Engine, Manifest};
use linformer::training::{LrSchedule, TrainConfig, Trainer};
use linformer::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            ("steps", "training steps (default 300)"),
            ("model", "manifest model (default serve_128)"),
            ("lr", "peak lr (default 1e-3)"),
            ("checkpoint", "path to save the final checkpoint"),
        ],
    )?;
    let steps = args.usize_or("steps", 300)?;
    let model = args.str_or("model", "serve_128");

    let manifest = Manifest::load("artifacts")?;
    let entry = manifest.model(&model)?;
    println!(
        "== end-to-end MLM pretraining ==\n\
         model {model}: n={}, k={}, {:?}/{:?}, {} params, batch {}",
        entry.config.max_len,
        entry.config.k_proj,
        entry.config.attention,
        entry.config.sharing,
        entry.param_count,
        entry.batch,
    );

    let engine = Engine::cpu()?;
    let mut trainer = Trainer::new(&engine, entry)?;
    let cfg = TrainConfig {
        steps,
        schedule: LrSchedule::linear(
            args.f64_or("lr", 1e-3)? as f32,
            steps / 10,
            steps,
        ),
        eval_every: (steps / 8).max(1),
        eval_batches: 4,
        log_every: (steps / 30).max(1),
        seed: 0,
        verbose: true,
    };
    let report = trainer.run(&cfg)?;

    println!("\nloss curve (step, train_loss, eval_loss):");
    for p in &report.points {
        match p.eval_loss {
            Some(e) => println!("  {:>5}  {:.4}  {:.4}", p.step, p.loss, e),
            None => println!("  {:>5}  {:.4}  -", p.step, p.loss),
        }
    }
    println!(
        "\nfinal: eval loss {:.4}, perplexity {:.1}, {:.2} steps/s \
         ({} steps, wall {:.1}s)",
        report.final_eval_loss,
        report.final_perplexity,
        report.steps_per_sec,
        steps,
        steps as f64 / report.steps_per_sec,
    );
    let first = report.points.first().map(|p| p.loss).unwrap_or(f32::NAN);
    if report.final_eval_loss < first {
        println!("✓ loss decreased — the full stack trains end to end");
    } else {
        println!("✗ loss did not decrease — investigate!");
        std::process::exit(1);
    }
    if let Some(path) = args.get("checkpoint") {
        trainer.save_checkpoint(path)?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}
